"""Standalone runner: the continuous-batching engine on a (2,4) mesh —
6 staggered requests through 4 slots must terminate with exactly the
tokens one-at-a-time serving produces, in BOTH decode modes (exact
flash-decoding and the paper-faithful prism Segment-Means cache).

Both paths run the identical per-row computation (prefill rows are
batch-independent, decode rows are owner-masked), so greedy token ids
match bit-for-bit regardless of which slot a request lands in or which
other requests share the step.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.runtime.serve import ServeHParams
from repro.serving import ServingEngine


def check(mode: str) -> bool:
    cfg = ModelConfig(
        name="tiny-dense", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode=mode, ssm_chunk=8, means_cr=4.0)
    kw = dict(n_slots=4, prefill_len=32, max_cache=48, hp=hp)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(6)]

    eng = ServingEngine(cfg, mesh, params, **kw)
    for p in prompts[:4]:
        eng.submit(p, max_new_tokens=8)
    for _ in range(4):                       # decode before late arrivals
        eng.step()
    for p in prompts[4:]:
        eng.submit(p, max_new_tokens=8)
    concurrent = eng.run()

    seq = ServingEngine(cfg, mesh, params, **kw)
    ok = True
    for i, p in enumerate(prompts):
        rid = seq.submit(p, max_new_tokens=8)
        out = seq.run()[rid]
        match = concurrent[i] == out
        ok &= match
        print(f"[{mode}] request {i}: {'OK' if match else 'MISMATCH'} "
              f"{concurrent[i]} vs {out}")
    s = eng.stats.summary()
    ok &= eng.stats.completed == 6 and s["occupancy"] > 0
    print(f"[{mode}] occupancy={s['occupancy']:.2f} "
          f"prefills={s['prefills']} decode_steps={s['decode_steps']}")
    return ok


def main():
    ok = check("exact")
    ok &= check("prism")
    print("ALL OK" if ok else "ENGINE FAILURES")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Standalone runner: the continuous-batching engine on a (2,4) mesh —
6 staggered requests through 4 slots must terminate with exactly the
tokens one-at-a-time serving produces, in BOTH decode modes (exact
flash-decoding and the paper-faithful prism Segment-Means cache), with
the prompt split across MULTIPLE prefill chunks (chunk_len < prompt
length) AND in token-packed mode (one ragged mixed prefill+decode
program per tick, token_budget not a multiple of the live token
count), so prefill tokens of different requests pack into the same
tick as in-flight decodes.

All paths run the identical per-token computation (packed/chunk rows
are request-isolated, decode rows are owner-masked, and the cache is
addressed purely by (slot, position)), so greedy token ids match
bit-for-bit regardless of which slot a request lands in, which other
requests share the tick, or how its prompt was split.  Exact mode is
additionally pinned against a teacher-forced ``T.forward`` oracle
that shares none of the serving code.

The (2,4) mesh matters doubly for packed mode: the cache batch dim is
sharded over 'data', so packed tokens must route their writes/reads to
the one (batch, sequence) shard pair owning their cache address — the
replicated-token, psum-over-all-axes path this runner pins.

The packed cells additionally pin the async streaming loop
(``serving/streaming.py``): the double-buffered engine — device-side
argmax, speculative next-tick dispatch, single ``ResultTokens`` copy
home per tick — must stream exactly the synchronous engine's tokens on
the same sharded mesh, in exact AND prism decode modes.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.runtime.serve import ServeHParams
from repro.serving import ServingEngine


CFG = ModelConfig(
    name="tiny-dense", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def check(mode: str, chunk_len: int, *, ground_truth: bool = False,
          prefill_mode: str = "chunked", token_budget: int = 11,
          paged: bool = True) -> bool:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = T.init(CFG, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode=mode, ssm_chunk=8, means_cr=4.0)
    kw = dict(n_slots=4, prefill_len=32, max_cache=48, hp=hp,
              chunk_len=chunk_len, prefill_mode=prefill_mode,
              token_budget=token_budget)
    tag = f"{mode}/{prefill_mode}/c{chunk_len}"

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(6)]

    eng = ServingEngine(CFG, mesh, params, paged=paged, **kw)
    for p in prompts[:4]:
        eng.submit(p, max_new_tokens=8)
    for _ in range(4):                       # decode before late arrivals
        eng.step()
    for p in prompts[4:]:
        eng.submit(p, max_new_tokens=8)
    concurrent = eng.run()

    # the sequential oracle runs on the DENSE rowset, so this check
    # doubles as the paged ≡ unpaged equivalence pin (exact mode is
    # further pinned below against T.forward, which shares no serving
    # code at all)
    seq = ServingEngine(CFG, mesh, params, paged=False, **kw)
    ok = True
    for i, p in enumerate(prompts):
        rid = seq.submit(p, max_new_tokens=8)
        out = seq.run()[rid]
        match = concurrent[i] == out
        ok &= match
        print(f"[{tag}] request {i}: {'OK' if match else 'MISMATCH'} "
              f"{concurrent[i]} vs {out}")
    s = eng.stats.summary()
    ok &= eng.stats.completed == 6 and s["occupancy"] > 0
    if prefill_mode == "packed":
        # prompts of 8..32 tokens against a ragged budget of 11 mixed
        # tokens must spread over several packed ticks
        ok &= s["packed_ticks"] > 6
        ok &= s["packed_prefill_tokens"] == s["prefill_tokens"]
    elif chunk_len < 32:
        # prompts of 8..32 tokens at chunk_len < 8 must take > 1 chunk
        ok &= s["prefill_chunks"] > 6
    print(f"[{tag}] occupancy={s['occupancy']:.2f} "
          f"prefills={s['prefills']} chunks={s['prefill_chunks']} "
          f"packed_ticks={s['packed_ticks']} "
          f"prefill_tokens={s['prefill_tokens']} "
          f"decode_steps={s['decode_steps']}")

    if prefill_mode == "packed" and paged:
        # streamed ≡ sync on the sharded mesh: the double-buffered
        # overlapped streaming loop (serving/streaming.py) replays the
        # same staggered trace — the device-side argmax carried home in
        # each tick's ResultTokens array must reproduce the synchronous
        # engine's host-sampled tokens bit-for-bit, per stream, in BOTH
        # decode modes (the merge/pack programs run under the same
        # GSPMD partitioning as the packed tick itself)
        from repro.serving import StreamingEngine
        eng_s = ServingEngine(CFG, mesh, params, paged=True, **kw)
        seng = StreamingEngine(eng_s, overlap=True)
        streams = []
        for p in prompts[:4]:
            streams.append(seng.submit_stream(p, max_new_tokens=8)[1])
        for _ in range(4):                   # stagger, as in the oracle
            seng.step()
        for p in prompts[4:]:
            streams.append(seng.submit_stream(p, max_new_tokens=8)[1])
        streamed = seng.run_sync()
        match = streamed == concurrent
        ok &= match
        ok &= all(streams[i].drain() == concurrent[i] for i in range(6))
        ok &= (eng_s.stats.tokens_streamed
               == sum(len(v) for v in concurrent.values()))
        print(f"[{tag}] streamed-vs-sync: "
              f"{'OK' if match else 'MISMATCH'} "
              f"(tokens_streamed={eng_s.stats.tokens_streamed}, "
              f"ticks_idle={eng_s.stats.ticks_idle})")

    if ground_truth:
        # exact mode only: pin against teacher-forced full forward
        for i in (0, 1):
            toks = list(prompts[i])
            for _ in range(8):
                logits, _ = T.forward(CFG, params, jnp.asarray([toks]),
                                      chunk=8)
                toks.append(int(np.argmax(np.asarray(logits[0, -1]))))
            want = toks[len(prompts[i]):]
            match = concurrent[i] == want
            ok &= match
            print(f"[{tag}] request {i} vs T.forward: "
                  f"{'OK' if match else 'MISMATCH'}")

    if paged:
        # forced-preemption variant: the same trace with the host
        # offload tier on, every request spilled to the KVStore once
        # mid-decode (pages + prism kz/vz/gz/zsum state in one
        # device->host gather) and restored through the page-aware
        # admission path — final tokens must still equal the
        # uninterrupted oracle's, pinning spill/restore bit-equality
        # on the sharded mesh in BOTH decode modes
        pre = ServingEngine(CFG, mesh, params, paged=True, offload=True,
                            **kw)
        for p in prompts[:4]:
            pre.submit(p, max_new_tokens=8)
        for _ in range(4):
            pre.step()
        for p in prompts[4:]:
            pre.submit(p, max_new_tokens=8)
        hit = set()
        for _ in range(2000):
            if not pre._sched.has_work and not pre._pending:
                break
            pre.step()
            for st in list(pre._sched.active.values()):
                rid = st.req.rid
                if (rid not in hit and not st.prefilling
                        and len(st.generated) >= 1 and not st.finished()):
                    assert pre.preempt(rid)
                    hit.add(rid)
        forced = pre.results()
        match = forced == concurrent and len(hit) == 6
        ok &= match
        st6 = pre.stats
        ok &= st6.preemptions >= 6 and st6.restore_hits >= 6
        ok &= st6.restore_misses == 0 and len(pre.kv_store) == 0
        pre.kv_cache.check()
        print(f"[{tag}] forced-preempt: {'OK' if match else 'MISMATCH'} "
              f"(preemptions={st6.preemptions} "
              f"spilled_pages={st6.spilled_pages} "
              f"restore_hits={st6.restore_hits})")

    if paged and prefill_mode == "packed":
        # kill-and-restore: run the engine to a mixed mid-flight moment
        # (some slots decoding, some prefilling, >= 1 request spilled
        # to the host store), journal it with snapshot(), TEAR THE
        # ENGINE DOWN, and restore the journal into a fresh engine —
        # which must finish the trace with tokens identical to the
        # uninterrupted oracle's, in BOTH decode modes on the sharded
        # (2,4) mesh (the prism kz/vz/gz/zsum state rows ride the same
        # journalled gather the offload tier uses)
        eng1 = ServingEngine(CFG, mesh, params, paged=True, offload=True,
                             **kw)
        for p in prompts[:4]:
            eng1.submit(p, max_new_tokens=8)
        for _ in range(200):
            eng1.step()
            act = list(eng1._sched.active.values())
            dec = [st for st in act
                   if not st.prefilling and st.generated
                   and not st.finished()]
            pref = [st for st in act if st.prefilling]
            if len(dec) >= 2 and pref:
                break
        else:
            raise AssertionError("no mixed prefill+decode moment")
        for p in prompts[4:]:
            eng1.submit(p, max_new_tokens=8)
        assert eng1.preempt(dec[0].req.rid)       # >= 1 spilled
        assert len(eng1.kv_store) == 1
        snap = eng1.snapshot()
        n_active = len(snap.active)
        del eng1                                  # the crash

        eng2 = ServingEngine(CFG, mesh, params, paged=True, offload=True,
                             **kw)
        eng2.restore(snap)
        assert len(eng2._sched.active) == n_active
        assert len(eng2.kv_store) == 1
        restored = eng2.run()
        match = restored == concurrent
        ok &= match
        ok &= eng2.stats.restore_misses == 0
        ok &= eng2.stats.completed == 6 and len(eng2.kv_store) == 0
        eng2.kv_cache.check()
        print(f"[{tag}] kill-and-restore: "
              f"{'OK' if match else 'MISMATCH'} "
              f"(journalled {n_active} live slots + 1 spilled; "
              f"restore_hits={eng2.stats.restore_hits})")
    return ok


def check_degraded(mode: str) -> bool:
    """Degraded-mesh cell: kill one sequence shard mid-decode on the
    (2,4) mesh and pin the shard-loss contract in BOTH decode modes —

    * every stream terminates, finite, with exactly ``max_new`` tokens
      (the first few from the Segment-Means standby-replica substitute
      path, the rest exact after recovery);
    * recovered / re-prefilled requests finish token-identical to the
      uninterrupted oracle (``results()`` compares ALL requests,
      including ones admitted after recovery);
    * the degraded window is observable (``shard_lost >= 1``,
      ``degraded_ticks >= 1``) and the drained engine is leak-free.

    The StreamingEngine wrapper runs synchronously here by
    construction (any FaultPlan disables overlap — chaos semantics are
    per synchronous tick), which is exactly the drain the degraded
    window requires."""
    from repro.runtime.faults import FaultPlan, FaultSpec
    from repro.serving import StreamingEngine

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = T.init(CFG, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode=mode, ssm_chunk=8, means_cr=4.0)
    kw = dict(n_slots=4, prefill_len=32, max_cache=48, hp=hp,
              chunk_len=8, prefill_mode="packed", token_budget=11,
              paged=True)
    tag = f"{mode}/degraded"
    gen = 8

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(6)]

    oracle_eng = ServingEngine(CFG, mesh, params, **kw)
    for p in prompts:
        oracle_eng.submit(p, max_new_tokens=gen)
    oracle = oracle_eng.run()

    ok = True
    for shard in (0, 1):
        plan = FaultPlan(shard_loss=FaultSpec(at=(6,), shard=shard))
        eng = ServingEngine(CFG, mesh, params, faults=plan, **kw)
        seng = StreamingEngine(eng)
        assert not seng.overlap        # injector forces sync ticks
        streams = []
        for p in prompts[:4]:          # in flight when the shard dies
            streams.append(seng.submit_stream(p, max_new_tokens=gen)[1])
        kinds = []
        for _ in range(2000):
            kinds.append(seng.step())
            if len(prompts) > len(streams) and "recovered" in kinds:
                # admitted strictly after recovery: must be exact
                for p in prompts[4:]:
                    streams.append(
                        seng.submit_stream(p, max_new_tokens=gen)[1])
            if not seng.has_work:
                break
        got = eng.results()
        match = got == oracle
        ok &= match
        delivered = [s.drain() for s in streams]
        finite = all(len(d) == gen and all(isinstance(t, int) for t in d)
                     for d in delivered)
        ok &= finite
        ok &= all(s.finished in ("length", "eos") for s in streams)
        # post-recovery suffix of every stream is exact: it can only
        # contain tokens re-derived by the deterministic re-prefill
        ok &= all(d[-1] == oracle[i][-1]
                  for i, d in enumerate(delivered))
        s = eng.stats.summary()
        ok &= s["shard_lost"] >= 1 and s["degraded_ticks"] >= 1
        ok &= "degraded" in kinds and "recovered" in kinds
        # zero-leak audit (same checks as the chaos drill)
        kv = eng.kv_cache
        kv.check()
        leak_free = (not kv.slot_pages and not kv.slot_state
                     and sorted(eng._sched.free_slots) == list(range(4)))
        ok &= leak_free
        print(f"[{tag}] shard {shard} dies at tick 6: "
              f"{'OK' if match else 'MISMATCH'} streams_finite="
              f"{finite} leak_free={leak_free} "
              f"shard_lost={s['shard_lost']} "
              f"degraded_ticks={s['degraded_ticks']} "
              f"restarts={s['restarts']}")
    return ok


def main():
    ok = check("exact", 64)                # clamps to prefill_len: 1 flush
    ok &= check("exact", 8, ground_truth=True)   # 1-4 chunks per prompt
    ok &= check("prism", 8)
    # token-packed ticks: ragged 11-token budget of mixed prefill +
    # decode work, batch dim sharded over 'data' — both decode modes,
    # exact additionally vs the teacher-forced oracle
    ok &= check("exact", 8, ground_truth=True, prefill_mode="packed")
    ok &= check("prism", 8, prefill_mode="packed")
    # degraded-mesh serving: a sequence shard dies mid-decode; streams
    # stay finite through the Segment-Means standby replicas and
    # recovery returns to token-exact serving
    ok &= check_degraded("exact")
    ok &= check_degraded("prism")
    print("ALL OK" if ok else "ENGINE FAILURES")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Async streaming front-end tests (single CPU device, mesh 1x1; the
2x4 sharded equivalence runs via tests/engine_equiv_runner.py):

  * the double-buffered overlapped loop streams EXACTLY the tokens the
    synchronous engine produces, per request, in order;
  * overlap off (synchronous ticks, streaming delivery) matches too;
  * the asyncio front-end (``serve_stream``) delivers the same tokens
    through real ``async for`` consumers;
  * mid-flight cancellation drains the tick pipeline and releases the
    slot/pages with zero leaks while every OTHER stream is unaffected;
  * a forced preemption (spill to the host store) mid-pipeline
    reconciles cleanly — in-flight speculative rows are discarded as
    stale, the restored request continues token-identically.
"""
import asyncio

import numpy as np
import jax

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import ServingEngine, StreamingEngine, serve_stream

TINY = ModelConfig(
    name="tiny-stream", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)

_CACHE: dict = {}


def _setup():
    """Shared params/mesh/prompts + the synchronous reference tokens
    (computed once — every test compares against the same oracle)."""
    if _CACHE:
        return _CACHE
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, TINY.vocab_size,
                            size=int(rng.integers(3, 9))).tolist()
               for _ in range(6)]
    eng = _engine(params, mesh)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    _CACHE.update(mesh=mesh, params=params, prompts=prompts,
                  ref=eng.run())
    return _CACHE


def _engine(params, mesh, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("max_cache", 24)
    return ServingEngine(TINY, mesh, params, **kw)


def test_stream_tokens_match_sync_engine():
    """Overlapped double-buffered streaming is token-identical to the
    synchronous engine, stream-by-stream, and the overlap counters
    land in the stats summary."""
    s = _setup()
    eng = _engine(s["params"], s["mesh"])
    seng = StreamingEngine(eng, overlap=True)
    assert seng.overlap                  # packed + no injector: armed
    streams = {}
    for p in s["prompts"]:
        rid, stream = seng.submit_stream(p, max_new_tokens=6)
        streams[rid] = stream
    out = seng.run_sync()
    assert out == s["ref"]
    for rid, stream in streams.items():
        assert stream.drain() == s["ref"][rid]
        assert stream.finished == "length"
    summ = eng.stats.summary()
    assert summ["tokens_streamed"] == sum(
        len(v) for v in s["ref"].values())
    assert summ["packed_ticks"] > 0      # the overlapped path ran
    assert 0.0 <= summ["host_overhead_fraction"] < 1.0


def test_stream_overlap_off_matches():
    """overlap=False degrades to synchronous ticks with streaming
    delivery — same tokens, no in-flight pipeline ever builds."""
    s = _setup()
    eng = _engine(s["params"], s["mesh"])
    seng = StreamingEngine(eng, overlap=False)
    assert not seng.overlap
    streams = [seng.submit_stream(p, max_new_tokens=6)[1]
               for p in s["prompts"]]
    out = seng.run_sync()
    assert out == s["ref"]
    assert not seng._pipe
    for rid, stream in enumerate(streams):
        assert stream.drain() == s["ref"][rid]


def test_stream_async_frontend():
    """serve_stream: Poisson-style staggered arrivals consumed by real
    ``async for`` loops deliver the reference tokens and finish
    reasons."""
    s = _setup()
    eng = _engine(s["params"], s["mesh"])
    seng = StreamingEngine(eng, overlap=True)
    reqs = [dict(prompt=p, max_new_tokens=6, arrival=0.002 * i)
            for i, p in enumerate(s["prompts"])]
    got = asyncio.run(serve_stream(seng, reqs))
    assert {rid: g["tokens"] for rid, g in sorted(got.items())} == s["ref"]
    assert all(g["finished"] == "length" for g in got.values())
    # wall-clock delivery timestamps are monotone within a stream
    for g in got.values():
        assert g["times"] == sorted(g["times"])


def test_stream_cancel_mid_flight_zero_leak():
    """Cancelling a decoding request mid-pipeline drains in-flight
    ticks, frees its pages/slot (zero leaks), closes its stream with
    reason 'cancelled', and leaves every other stream token-identical.
    prefix_cache off so the page pool must return to exactly full."""
    s = _setup()
    eng = _engine(s["params"], s["mesh"], prefix_cache=False)
    seng = StreamingEngine(eng, overlap=True)
    rids, streams = [], {}
    for p in s["prompts"]:
        rid, stream = seng.submit_stream(p, max_new_tokens=6)
        rids.append(rid)
        streams[rid] = stream
    for _ in range(6):                   # some ticks in flight
        seng.step()
    victim = rids[0]
    assert seng.cancel(victim)
    assert not seng._pipe                # cancel drained the pipeline
    assert streams[victim].finished == "cancelled"
    out = seng.run_sync()
    assert victim not in out
    assert eng.failed()[victim] == "cancelled"
    for rid in rids[1:]:
        assert out[rid] == s["ref"][rid]
        assert streams[rid].drain() == s["ref"][rid]
    assert eng.stats.cancelled == 1
    # zero-leak audit: every page, state row, and slot back in its pool
    kv = eng.kv_cache
    kv.check()
    assert not kv.slot_pages and not kv.slot_state
    assert kv.table.free_pages == kv.paging.n_pages
    assert sorted(eng._sched.free_slots) == list(range(4))


def test_stream_forced_preemption_reconciles():
    """Double-buffer reconciliation under preemption: spill an active
    request to the host store while speculative rows are in flight.
    preempt() drains first, the spilled request restores through
    normal admission, and every stream still matches the reference —
    the epoch/identity staleness checks make the race unobservable."""
    s = _setup()
    eng = _engine(s["params"], s["mesh"], offload=True,
                  prefix_cache=False)
    seng = StreamingEngine(eng, overlap=True)
    rids, streams = [], {}
    for p in s["prompts"]:
        rid, stream = seng.submit_stream(p, max_new_tokens=6)
        rids.append(rid)
        streams[rid] = stream
    # run until something is decoding with ticks in flight
    for _ in range(32):
        seng.step()
        victim = next((st.req.rid for st in eng._sched.active.values()
                       if not st.prefilling), None)
        if victim is not None and seng._pipe:
            break
    assert victim is not None and seng._pipe
    assert seng.preempt(victim)
    assert not seng._pipe                # preempt drained first
    out = seng.run_sync()
    assert out == s["ref"]               # spill/restore changed nothing
    assert eng.stats.preemptions >= 1
    assert eng.stats.restore_hits >= 1
    for rid in rids:
        assert streams[rid].drain() == s["ref"][rid]
        assert streams[rid].finished == "length"
    # no in-flight bookkeeping left behind
    assert all(st.inflight == 0
               for st in eng._sched.active.values())
    assert len(eng.kv_store) == 0

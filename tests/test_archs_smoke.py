"""Per-assigned-architecture smoke tests (assignment requirement):
instantiate a REDUCED variant of the same family (≤2 layers, d_model≤512,
≤4 experts) and run one forward + one train step on CPU, asserting output
shapes and the absence of NaNs.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.runtime.train import make_train_step, TrainHParams

B, N = 2, 32


def smoke_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(ks[1], (B, N), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "encodec_stub":
        batch["embeds"] = jax.random.normal(ks[0], (B, N, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, N), 0,
                                             cfg.vocab_size)
        if cfg.arch_type == "vlm":
            batch["embeds"] = jax.random.normal(
                ks[2], (B, cfg.prefix_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert (cfg.n_experts or 0) <= 4
    params = T.init(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = T.forward(cfg, params, batch.get("tokens"),
                            embeds=batch.get("embeds"), chunk=8)
    n_out = N if cfg.frontend != "encodec_stub" else N
    assert logits.shape == (B, n_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    prism = PrismConfig(P=1, cr=4.0, mode="prism")
    hp = TrainHParams(lr=1e-3, warmup=1, loss_chunks=4, ssm_chunk=8)
    step, rules, psh, osh, bsh = make_train_step(cfg, mesh, params,
                                                 prism, hp)
    opt = jax.device_put(adamw_init(params), osh)
    params = jax.device_put(params, psh)
    batch = jax.device_put(smoke_batch(cfg, jax.random.PRNGKey(1)), bsh)
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_opt["step"]) == 1
    # second step: warmed-up lr > 0 — parameters must move
    new_params, new_opt, metrics = step(new_params, new_opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(jax.device_get(new_params)),
                        jax.tree.leaves(jax.device_get(
                            T.init(cfg, jax.random.PRNGKey(0))))))
    assert moved


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-1.3b", "zamba2-2.7b",
                                  "olmoe-1b-7b", "gemma3-1b"])
def test_reduced_decode_step(arch):
    """Representative decode smoke (one arch per family): prefill 16,
    decode 2 tokens, finite logits of the right shape."""
    from repro.runtime.serve import (ServeHParams, grow_cache,
                                     make_prefill_step, make_serve_step)
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    n, gen = 16, 2
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    prism = PrismConfig(P=1, mode="voltage")
    prefill, lay_p, _, _ = make_prefill_step(cfg, mesh, params, prism,
                                             batch=B, n=n, hp=hp)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, n + gen), 0,
                                cfg.vocab_size)
    logits, cache = prefill(params, {"tokens": tokens[:, :n]})
    assert logits.shape == (B, cfg.vocab_size)
    step, lay_d, _, _ = make_serve_step(cfg, mesh, params, batch=B,
                                        cap=n + gen, prefill_len=n, hp=hp)
    cache = grow_cache(cache, lay_p, lay_d)
    for g in range(gen):
        logits, cache = step(params, cache, tokens[:, n + g],
                             jnp.full((B,), n + g, jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

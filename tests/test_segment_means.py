"""Segment Means (paper §IV-B, Alg. 2): unit + property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.segment_means import (
    segment_means, segment_sizes, segment_bounds, duplicate_means,
    num_landmarks, compression_rate)


def test_sizes_basic():
    assert segment_sizes(10, 3).tolist() == [3, 3, 4]
    assert segment_sizes(9, 3).tolist() == [3, 3, 3]
    assert segment_sizes(5, 1).tolist() == [5]
    assert segment_sizes(5, 5).tolist() == [1, 1, 1, 1, 1]


def test_sizes_invalid():
    with pytest.raises(ValueError):
        segment_sizes(3, 4)
    with pytest.raises(ValueError):
        segment_sizes(3, 0)


def test_bounds_cover_and_offset():
    lo, hi = segment_bounds(10, 3, offset=7)
    assert lo.tolist() == [7, 10, 13]
    assert hi.tolist() == [9, 12, 16]


def test_means_exact_values():
    x = jnp.arange(12.0).reshape(6, 2)
    z = segment_means(x, 3)
    np.testing.assert_allclose(
        np.asarray(z), [[1.0, 2.0], [5.0, 6.0], [9.0, 10.0]])


def test_means_ragged_tail():
    x = jnp.arange(10.0)[:, None]
    z = segment_means(x, 3)          # segments of 3,3,4
    np.testing.assert_allclose(np.asarray(z)[:, 0], [1.0, 4.0, 7.5])


@settings(deadline=None, max_examples=50)
@given(n=st.integers(1, 64), l_frac=st.floats(0.01, 1.0),
       d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_property_means_in_convex_hull(n, l_frac, d, seed):
    """Each segment mean lies within [min, max] of its segment — and the
    grand mean of (size-weighted) means equals the sequence mean."""
    L = max(1, int(n * l_frac))
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    z = np.asarray(segment_means(jnp.asarray(x), L))
    sizes = segment_sizes(n, L)
    lo, hi = segment_bounds(n, L)
    for i in range(L):
        seg = x[lo[i]:hi[i] + 1]
        assert (z[i] >= seg.min(0) - 1e-5).all()
        assert (z[i] <= seg.max(0) + 1e-5).all()
    weighted = (z * sizes[:, None]).sum(0) / n
    np.testing.assert_allclose(weighted, x.mean(0), atol=1e-5)


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 48), L=st.integers(1, 8))
def test_property_duplicate_restores_length(n, L):
    if L > n:
        L = n
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, 3)),
                    jnp.float32)
    z = segment_means(x, L)
    y = duplicate_means(z, n)
    assert y.shape == (n, 3)
    # constant sequences compress losslessly
    c = jnp.ones((n, 3))
    np.testing.assert_allclose(
        np.asarray(duplicate_means(segment_means(c, L), n)), 1.0)


def test_landmarks_eq16():
    # L = floor(N / (CR * P)) — paper Eq. 16
    assert num_landmarks(4096, 16.0, 16) == 16
    assert num_landmarks(197, 9.9, 2) == 9
    assert num_landmarks(8, 100.0, 2) == 1     # clamped
    assert compression_rate(4096, 16, 16) == 16.0


def test_batched_shapes():
    x = jnp.zeros((2, 3, 10, 4))
    assert segment_means(x, 3).shape == (2, 3, 3, 4)

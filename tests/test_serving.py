"""Serving-engine tests: scheduler policy, sampling, the slot-insert
round trip, and the 6-requests/4-slots continuous-batching equivalence
— all on the single real CPU device (mesh 1x1; the sharded version runs
via tests/engine_equiv_runner.py)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serve import (ServeHParams, grow_cache, init_cache,
                                 insert_cache_row, make_prefill_step,
                                 make_serve_step, reset_cache_row)
from repro.serving import (FifoScheduler, Request, SamplingParams,
                           ServingEngine, sample_token)


TINY = ModelConfig(
    name="tiny-serve", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _req(rid, prompt=(1, 2, 3), **kw):
    kw.setdefault("max_new_tokens", 4)
    return Request(rid=rid, prompt=tuple(prompt), **kw)


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------

def test_scheduler_fifo_admission_order():
    s = FifoScheduler(2)
    for i in range(3):
        s.submit(_req(i))
    assert s.want_prefill()                        # idle pool -> admit now
    states = s.admit(now=0.0)
    # FIFO into ascending slots
    assert [st.req.rid for st in states] == [0, 1]
    assert [st.slot for st in states] == [0, 1]
    assert not s.want_prefill()                    # queue yes, but no slot


def test_scheduler_interleave_ratio_protects_decode():
    s = FifoScheduler(4, decode_per_prefill=3)
    s.submit(_req(0))
    s.admit(now=0.0)
    s.submit(_req(1))
    # slots are free but a stream is decoding: hold the prefill until
    # `decode_per_prefill` decode steps have run
    assert not s.want_prefill()
    for _ in range(3):
        s.note_decode()
    assert s.want_prefill()


def test_scheduler_eviction_recycles_lowest_slot():
    s = FifoScheduler(3)
    for i in range(3):
        s.submit(_req(i))
    states = s.admit(now=0.0)
    s.evict(states[1], now=1.0)                    # free middle slot 1
    s.evict(states[0], now=1.0)                    # free slot 0
    assert s.free_slots == [0, 1]
    s.submit(_req(3))
    s.submit(_req(4))
    for _ in range(10):
        s.note_decode()
    new = s.admit(now=2.0)
    assert [st.slot for st in new] == [0, 1]       # lowest slot first
    assert states[1].t_finish == 1.0


def test_scheduler_chunk_interleave_fairness():
    """A long prompt mid-prefill can never starve decoding slots: while
    anything is decoding, chunks are granted at most once per
    ``decode_per_prefill`` decode steps — never back-to-back."""
    s = FifoScheduler(4, decode_per_prefill=3)
    s.submit(_req(0))
    s.admit(now=0.0)[0].begin_decode()             # a running stream
    s.submit(_req(1, prompt=tuple(range(1, 33))))  # long prompt
    assert s.want_admit()
    s.admit(now=0.0)
    assert s.prefilling() and s.decoding()
    grants = []
    for _ in range(12):                            # drive the policy
        if s.want_chunk():
            grants.append("chunk")
            s.note_chunk()
        else:
            grants.append("decode")
            s.note_decode()
    # never two chunks in a row, and >= decode_per_prefill decodes
    # between consecutive chunk grants
    last = None
    for i, g in enumerate(grants):
        if g == "chunk":
            if last is not None:
                assert i - last > 3, grants
            last = i
    assert grants.count("chunk") >= 2              # prefill does advance

    # nothing decoding -> chunks run back-to-back (TTFT is all that
    # matters for an otherwise-idle engine)
    s2 = FifoScheduler(2, decode_per_prefill=3)
    s2.submit(_req(0, prompt=tuple(range(1, 20))))
    s2.admit(now=0.0)
    assert s2.want_chunk()
    s2.note_chunk()
    assert s2.want_chunk()


def test_scheduler_want_admit_gang_vs_fifo():
    """Chunked admission is host-side and immediate in FIFO mode, but
    gang mode still only admits a full gang into an empty pool."""
    s = FifoScheduler(2)
    s.submit(_req(0))
    assert s.want_admit()                          # free slot + queue
    s.admit(now=0.0)[0].begin_decode()
    s.submit(_req(1))
    assert s.want_admit()                          # decode never blocks it

    g = FifoScheduler(2, gang=True)
    g.submit(_req(0))
    assert not g.want_admit()                      # waits for a full gang
    g.submit(_req(1))
    assert g.want_admit()
    states = g.admit(now=0.0)
    g.submit(_req(2))
    assert not g.want_admit()                      # pool busy
    g.evict(states[0], now=1.0)
    g.evict(states[1], now=1.0)
    g.drain = True
    assert g.want_admit()                          # drain-time remainder


def test_scheduler_gang_is_static_batching():
    s = FifoScheduler(2, gang=True)
    s.submit(_req(0))
    assert not s.want_prefill()                    # waits for a full gang
    s.submit(_req(1))
    s.submit(_req(2))
    assert s.want_prefill()
    states = s.admit(now=0.0)
    assert len(states) == 2
    s.submit(_req(3))
    assert not s.want_prefill()                    # pool busy: no admission
    s.evict(states[0], now=1.0)
    assert not s.want_prefill()                    # still draining
    s.evict(states[1], now=1.0)
    s.drain = True                                 # no more arrivals
    assert s.want_prefill()                        # flush the partial gang


# --------------------------------------------------------------------------
# sampling
# --------------------------------------------------------------------------

def test_sampling_greedy_temperature_topk():
    logits = np.array([0.1, 3.0, -1.0, 2.9, 0.0], np.float32)
    sp = SamplingParams()
    assert sample_token(logits, sp, sp.make_rng()) == 1

    # top-k=2 restricts support to the two largest logits
    sp = SamplingParams(temperature=5.0, top_k=2, seed=0)
    rng = sp.make_rng()
    draws = {sample_token(logits, sp, rng) for _ in range(64)}
    assert draws <= {1, 3} and len(draws) == 2

    # per-seed determinism
    sp = SamplingParams(temperature=1.0, seed=7)
    a = [sample_token(logits, sp, sp.make_rng()) for _ in range(1)]
    b = [sample_token(logits, sp, sp.make_rng()) for _ in range(1)]
    assert a == b


# --------------------------------------------------------------------------
# slot insert round trip
# --------------------------------------------------------------------------

def test_slot_insert_round_trip():
    """Prefill one request, insert its cache row into slot 2 of a 4-slot
    decode cache, and decode: the slot must match the plain batch=1
    serve path token for token."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    n0, cap, gen = 8, 16, 4
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    prism = PrismConfig(P=1, mode="voltage")
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, n0), 1,
                                TINY.vocab_size)

    # reference: batch=1 prefill + decode
    pre1, lp1, _, _ = make_prefill_step(TINY, mesh, params, prism,
                                        batch=1, n=n0, hp=hp)
    logits1, cache1 = pre1(params, {"tokens": prompt})
    step1, ld1, _, _ = make_serve_step(TINY, mesh, params, batch=1,
                                       cap=cap, prefill_len=n0, hp=hp)
    cache1 = grow_cache(cache1, lp1, ld1)

    # engine-style: batch=4 prefill (row 0 = the request), insert row 0
    # into slot 2 of a zeroed 4-slot cache
    pre4, lp4, _, _ = make_prefill_step(TINY, mesh, params, prism,
                                        batch=4, n=n0, hp=hp)
    junk = jax.random.randint(jax.random.PRNGKey(2), (4, n0), 1,
                              TINY.vocab_size)
    batch4 = jnp.concatenate([prompt, junk[1:]], axis=0)
    _, cache4 = pre4(params, {"tokens": batch4})
    step4, ld4, _, _ = make_serve_step(TINY, mesh, params, batch=4,
                                       cap=cap, prefill_len=n0, hp=hp)
    big = init_cache(TINY, ld4, 4, hp)
    big = insert_cache_row(big, grow_cache(cache4, lp4, ld4), 0, 2)

    tok = int(jnp.argmax(logits1[0]))
    for g in range(gen):
        pos1 = jnp.full((1,), n0 + g, jnp.int32)
        logits1, cache1 = step1(params, cache1,
                                jnp.full((1,), tok, jnp.int32), pos1)
        pos4 = jnp.asarray([-1, -1, n0 + g, -1], jnp.int32)
        tok4 = jnp.asarray([0, 0, tok, 0], jnp.int32)
        logits4, big = step4(params, big, tok4, pos4)
        got, ref = np.asarray(logits4[2]), np.asarray(logits1[0])
        err = np.abs(got - ref).max() / max(1e-6, np.abs(ref).max())
        assert err < 1e-5, (g, err)
        tok = int(np.argmax(ref))

    # reset_cache_row zeroes exactly the one batch row
    assert np.asarray(big["scan"][0]["k"][:, 2]).any()
    wiped = reset_cache_row(big, 2)
    leaf = np.asarray(wiped["scan"][0]["k"])        # (n_units, B, cap, H, hd)
    assert not leaf[:, 2].any()


# --------------------------------------------------------------------------
# engine end-to-end
# --------------------------------------------------------------------------

def _engine(params, mesh, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("max_cache", 24)
    return ServingEngine(TINY, mesh, params, **kw)


def test_engine_six_staggered_requests_match_sequential():
    """6 requests through a 4-slot engine — the last two admitted
    mid-flight into evicted slots — terminate with exactly the tokens
    sequential (one-at-a-time) serving produces."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, TINY.vocab_size,
                            size=int(rng.integers(3, 9))).tolist()
               for _ in range(6)]

    eng = _engine(params, mesh)
    for p in prompts[:4]:
        eng.submit(p, max_new_tokens=6)
    for _ in range(3):                     # stagger: decode before arrivals
        eng.step()
    for p in prompts[4:]:
        eng.submit(p, max_new_tokens=6)
    concurrent = eng.run()
    assert eng.stats.completed == 6
    # late arrivals joined mid-flight: their prompt tokens packed into
    # ticks beyond the opening burst (default mode is 'packed')
    assert eng.stats.packed_ticks >= 2
    assert eng.stats.packed_prefill_tokens == sum(len(p) for p in prompts)

    seq_eng = _engine(params, mesh)
    for i, p in enumerate(prompts):
        rid = seq_eng.submit(p, max_new_tokens=6)
        out = seq_eng.run()[rid]
        assert concurrent[i] == out, (i, concurrent[i], out)

    stats = eng.stats.summary()
    assert stats["requests"] == 6
    assert 0.0 < stats["occupancy"] <= 1.0
    assert len(eng.stats.ttft) == 6


def test_engine_short_prompt_matches_full_forward():
    """Ground truth independent of the engine: a SHORT prompt (< the
    pad length) decoded greedily through the engine must match a
    teacher-forced T.forward loop — pins the pad+rewind admission
    against an oracle that shares none of its code."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    prompt = [7, 19, 3, 42, 11]                    # 5 < prefill_len = 8
    gen = 5

    eng = _engine(params, mesh)
    rid = eng.submit(prompt, max_new_tokens=gen)
    got = eng.run()[rid]

    seq = list(prompt)
    for _ in range(gen):
        logits, _ = T.forward(TINY, params, jnp.asarray([seq]), chunk=8)
        seq.append(int(np.argmax(np.asarray(logits[0, -1]))))
    assert got == seq[len(prompt):], (got, seq[len(prompt):])


def test_engine_eos_and_max_tokens_evict():
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, mesh, n_slots=2)
    rid0 = eng.submit([5, 6, 7], max_new_tokens=4)
    out0 = eng.run()[rid0]
    assert len(out0) == 4                  # max-tokens eviction

    # use the first generated token as EOS: the request must stop at 1
    eng2 = _engine(params, mesh, n_slots=2)
    rid1 = eng2.submit([5, 6, 7], max_new_tokens=4, eos_id=out0[0])
    out1 = eng2.run()[rid1]
    assert out1 == [out0[0]]


def test_engine_eviction_mid_prefill():
    """A decoding request finishes and is evicted WHILE another request
    is mid-prefill; a third request is admitted into the freed slot and
    its chunks interleave — everything still matches sequential
    serving."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    pa = rng.integers(1, TINY.vocab_size, size=3).tolist()
    pb = rng.integers(1, TINY.vocab_size, size=8).tolist()   # 4 chunks
    pc = rng.integers(1, TINY.vocab_size, size=5).tolist()

    kw = dict(n_slots=2, prefill_len=8, max_cache=24, chunk_len=2,
              decode_per_prefill=1)
    eng = _engine(params, mesh, **kw)
    ra = eng.submit(pa, max_new_tokens=2)
    while not eng._sched.decoding():               # finish A's prefill
        eng.step()
    rb = eng.submit(pb, max_new_tokens=4)
    saw_mid_prefill_evict = False
    while eng._sched.has_work:
        eng.step()
        if (ra in eng._results and eng._sched.prefilling()):
            saw_mid_prefill_evict = True
            break
    assert saw_mid_prefill_evict                   # A gone, B mid-prefill
    rc = eng.submit(pc, max_new_tokens=3)
    out = eng.run()
    assert set(out) == {ra, rb, rc}
    # slot reuse: C landed in A's freed slot
    assert eng._results[rc].slot == eng._results[ra].slot

    seq = _engine(params, mesh, **kw)
    for rid, p, g in ((ra, pa, 2), (rb, pb, 4), (rc, pc, 3)):
        srid = seq.submit(p, max_new_tokens=g)
        assert seq.run()[srid] == out[rid], rid


def test_engine_rejects_recurrent_and_ring_archs():
    """The padded-prefill + rewind admission scheme is only sound for
    position-addressed global attention caches — SSM state consumes pad
    tokens and the ring window cache holds the padded tail."""
    ssm = ModelConfig(
        name="tiny-xlstm", arch_type="ssm", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=61,
        blocks=("mlstm", "slstm"), norm_kind="rmsnorm", pos="none",
        ssm_heads=2, tie_embeddings=False)
    mesh = _mesh()
    params = T.init(ssm, jax.random.PRNGKey(0))
    try:
        ServingEngine(ssm, mesh, params, n_slots=2, prefill_len=8,
                      max_cache=16)
        raise AssertionError("SSM arch must be rejected")
    except ValueError as e:
        assert "mlstm" in str(e)


def test_engine_rejects_embed_frontends():
    """vlm/audio configs need 'embeds' prefill inputs the token-only
    admission path never builds — reject at construction, not with a
    pytree mismatch at the first flush.  (The guard runs before params
    are touched, so none are needed.)"""
    vlm = ModelConfig(
        name="tiny-vlm", arch_type="vlm", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
        norm_kind="rmsnorm", pos="rope", prefix_len=4)
    try:
        ServingEngine(vlm, _mesh(), None, n_slots=2, prefill_len=8,
                      max_cache=16)
        raise AssertionError("vlm arch must be rejected")
    except ValueError as e:
        assert "embedding inputs" in str(e)


def test_engine_run_with_logical_clock_terminates():
    """run() must finish under an injected non-wall clock: future
    arrivals fast-forward instead of spinning on time.sleep."""

    class Frozen:
        t = 0.0

        def __call__(self):
            return self.t

    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, mesh, n_slots=2, clock=Frozen())
    rid = eng.submit([4, 5, 6], max_new_tokens=3, arrival=7.5)
    out = eng.run()
    assert len(out[rid]) == 3
    # the clock was fast-forwarded past the arrival, not slept through
    assert eng.now() >= 7.5


def test_engine_rejects_oversized_requests():
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, mesh)
    try:
        eng.submit(list(range(1, 12)), max_new_tokens=2)
        raise AssertionError("prompt > prefill_len must be rejected")
    except ValueError:
        pass
    try:
        eng.submit([1, 2, 3], max_new_tokens=1000)
        raise AssertionError("prompt+gen > cache cap must be rejected")
    except ValueError:
        pass

"""Runtime-substrate tests: optimizer, losses, data pipeline, checkpoint,
sharding rules."""
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import CharTokenizer, lm_batches, synthetic_text
from repro.checkpoint.io import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)
from repro.runtime.losses import chunked_lm_loss, softmax_xent


def test_adamw_first_step_is_signed_lr():
    """After one step from zero state, update ≈ -lr·sign(g) (bias-corrected
    Adam with eps≈0) plus decay."""
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.full((4, 4), 2.0), "b": jnp.full((4,), -3.0)}
    state = adamw_init(params)
    new, st = adamw_update(params, grads, state, lr=0.1, weight_decay=0.0,
                           eps=1e-12)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.1, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(new["b"]), 0.1, rtol=1e-4)
    assert int(st["step"]) == 1
    # 1-D params get no weight decay
    new2, _ = adamw_update(params, grads, state, lr=0.1, weight_decay=0.5)
    assert not np.allclose(np.asarray(new2["w"]), np.asarray(new["w"]))
    np.testing.assert_allclose(np.asarray(new2["b"]), np.asarray(new["b"]),
                               rtol=1e-5)


def test_cosine_schedule():
    s = lambda t: float(cosine_schedule(jnp.asarray(t), base_lr=1.0,
                                        warmup=10, total=110))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert s(5) == 0.5
    assert abs(s(110) - 0.1) < 1e-6       # min_frac floor
    assert s(60) < s(20)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum((np.asarray(x) ** 2).sum()
                        for x in jax.tree.leaves(clipped)))
    assert abs(float(gn) - np.sqrt(48 + 36)) < 1e-4
    assert abs(total - 1.0) < 1e-4
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 4.0)


def test_chunked_lm_loss_matches_direct():
    b, n, d, v = 2, 16, 8, 32
    f = jax.random.normal(jax.random.PRNGKey(0), (b, n, d))
    tbl = jax.random.normal(jax.random.PRNGKey(1), (v, d))
    y = jax.random.randint(jax.random.PRNGKey(2), (b, n), 0, v)
    direct = softmax_xent(f @ tbl.T, y)
    for chunk in (1, 4, 16):
        got = chunked_lm_loss(f, tbl, y, chunk=chunk)
        np.testing.assert_allclose(float(got), float(direct), rtol=1e-5)


def test_pipeline_deterministic_and_shifted():
    tok = CharTokenizer()
    text = synthetic_text(5000, seed=3)
    assert len(text) == 5000
    assert text == synthetic_text(5000, seed=3)
    ids = tok.encode(text)
    assert tok.decode(ids) == text
    it1 = lm_batches(ids, batch=4, seq=16, seed=7)
    it2 = lm_batches(ids, batch=4, seq=16, seed=7)
    x1, y1 = next(it1)
    x2, y2 = next(it2)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(x1[:, 1:], y1[:, :-1])   # next-char


def test_checkpoint_roundtrip(tmp_path):
    tree = {"scan": [{"w": jnp.arange(6.0).reshape(2, 3)}],
            "tail": [], "step": jnp.asarray(7)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 3, tree)
    save_checkpoint(d, 9, tree)
    assert latest_step(d) == 9
    back = restore_checkpoint(d, 9, tree)
    np.testing.assert_array_equal(np.asarray(back["scan"][0]["w"]),
                                  np.asarray(tree["scan"][0]["w"]))


def test_sharding_rules_paths():
    from repro.sharding.rules import param_specs, spec_tree
    from repro.configs import get_config
    from repro.launch.inputs import param_shapes
    cfg = get_config("olmoe-1b-7b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeAx(dict):
        pass
    # build rules against the production axis sizes without 256 devices
    shapes = param_shapes(cfg)
    rules = param_specs(shapes, mesh, cfg.vocab_size)
    flat = jax.tree_util.tree_flatten_with_path(rules)[0]
    kinds = {}
    for path, rule in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        kinds[key] = rule.kind
    # experts present and tagged
    assert any(v == "expert" for v in kinds.values())
    # embed table tagged vocab (50304 divisible by 1)
    assert kinds["embed/table"] == "vocab"
    # stacked scan leaves carry a leading None in their spec
    for path, rule in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        if key.startswith("scan/") and len(rule.spec) > 0:
            assert rule.spec[0] is None

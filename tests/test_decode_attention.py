"""Flash-decode kernel validation: interpret-mode execution vs the jnp
oracles (`flash_decode_combine` / `prism_decode_attention`), sweeping
GQA ratios, ragged per-slot positions (idle pos = -1 rows), prism means
columns, and non-block-multiple cache lengths — plus the backend
dispatch rules and a serve-step integration check."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention import (decode_stats_reference,
                                            flash_decode_stats,
                                            merge_stats,
                                            partial_softmax_stats)
from repro.kernels.dispatch import (default_interpret, pallas_interpret,
                                    resolve_backend, use_pallas)
from repro.runtime.serve import (decode_attention, flash_decode_combine,
                                 prism_decode_attention)


def make_case(b, m_loc, hq, hkv, hd, *, mz=0, seed=0, pos=None):
    """Continuous-batching-shaped decode case: per-row positions (idle
    rows -1), prefix-valid columns, optional means columns with a
    per-row g (0 = dead: own shard / not-yet-covered segment)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, 1, hq, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, m_loc, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, m_loc, hkv, hd)) * 0.5
    if pos is None:
        rng = np.random.default_rng(seed)
        pos = rng.integers(-1, m_loc, size=b)
        pos[0] = m_loc - 1                       # one fully-deep slot
        if b > 1:
            pos[1] = -1                          # one idle slot
    pos = np.asarray(pos)
    valid = jnp.asarray(np.arange(m_loc)[None, :] <= pos[:, None])
    scale = hd ** -0.5
    if not mz:
        return q, k, v, valid, pos, scale
    kz = jax.random.normal(ks[3], (b, mz, hkv, hd)) * 0.5
    vz = jax.random.normal(ks[4], (b, mz, hkv, hd)) * 0.5
    gz = np.where(np.arange(mz)[None, :] % 3 == 0, 0.0, 4.0)
    gz = jnp.asarray(gz * (pos >= 0)[:, None].astype(np.float64),
                     jnp.float32)                # idle rows: all dead
    return q, k, v, valid, pos, scale, kz, vz, gz


GQA_GRID = [
    # b, m_loc, hq, hkv, hd      — m_loc deliberately off block multiples
    (4, 16, 2, 2, 16),           # MHA
    (3, 33, 8, 2, 32),           # GQA 4:1, ragged M
    (2, 100, 6, 3, 64),          # GQA 2:1, ragged M
    (2, 128, 8, 1, 64),          # MQA, block-aligned
    (1, 7, 4, 4, 16),            # shorter than one block
]


@pytest.mark.parametrize("b,m_loc,hq,hkv,hd", GQA_GRID)
def test_kernel_vs_combine_oracle(b, m_loc, hq, hkv, hd):
    """Kernel stats, locally combined, equal the dense flash-decode
    oracle on every live row (idle rows are garbage-but-finite in the
    oracle, exact zero in the stats path — both unobserved)."""
    q, k, v, valid, pos, scale = make_case(b, m_loc, hq, hkv, hd)
    want = flash_decode_combine(q, k, v, valid, (), scale)
    got = decode_attention(q, k, v, valid, (), scale, backend="pallas")
    live = pos >= 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               atol=1e-5, rtol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


@pytest.mark.parametrize("b,m_loc,hq,hkv,hd", GQA_GRID)
@pytest.mark.parametrize("mz", [6, 8])
def test_kernel_vs_prism_oracle(b, m_loc, hq, hkv, hd, mz):
    """Means columns folded in-kernel (+log g bias) equal the
    concatenate-then-softmax prism oracle, for both backends."""
    q, k, v, valid, pos, scale, kz, vz, gz = make_case(
        b, m_loc, hq, hkv, hd, mz=mz)
    owner = jnp.asarray(pos >= 0)
    want = prism_decode_attention(q, k, v, kz, vz, valid, gz, owner,
                                  (), scale)
    live = pos >= 0
    for backend in ("jnp", "pallas"):
        got = decode_attention(q, k, v, valid, (), scale, gz=gz, kz=kz,
                               vz=vz, owner=owner, mode="prism",
                               backend=backend)
        np.testing.assert_allclose(np.asarray(got)[live],
                                   np.asarray(want)[live],
                                   atol=1e-5, rtol=1e-5, err_msg=backend)


def test_kernel_stats_match_reference_stats():
    """The raw (m, l, acc) triples agree between kernel and jnp
    reference — the shard-combine contract, not just the combined
    output.  (m is only meaningful where l > 0.)"""
    q, k, v, valid, pos, scale, kz, vz, gz = make_case(
        3, 40, 4, 2, 32, mz=6, seed=3)
    log_gz = jnp.where(gz > 0, jnp.log(jnp.maximum(gz, 1e-30)), -1e30)
    m_k, l_k, a_k = flash_decode_stats(q, k, v, valid, log_gz, kz, vz,
                                       scale=scale, interpret=True)
    m_r, l_r, a_r = decode_stats_reference(q, k, v, valid, log_gz, kz,
                                           vz, scale=scale)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(a_k), np.asarray(a_r),
                               atol=1e-5, rtol=1e-5)
    alive = np.asarray(l_r) > 0
    np.testing.assert_allclose(np.asarray(m_k)[alive],
                               np.asarray(m_r)[alive],
                               atol=1e-6, rtol=1e-6)
    # idle rows carry exactly-empty stats, not garbage
    idle = ~(pos >= 0)
    assert not np.asarray(l_k)[idle].any()
    assert not np.asarray(a_k)[idle].any()


def test_merge_stats_is_concat():
    """Splitting the columns anywhere and merging the partial stats
    equals single-pass stats over all columns — the identity both the
    kernel grid and the cross-shard combine rest on."""
    q, k, v, valid, pos, scale = make_case(3, 24, 4, 2, 16, seed=5)
    bias = jnp.where(valid, 0.0, -1e30)
    whole = partial_softmax_stats(q, k, v, bias, scale)
    for cut in (1, 8, 23):
        a = partial_softmax_stats(q, k[:, :cut], v[:, :cut],
                                  bias[:, :cut], scale)
        b = partial_softmax_stats(q, k[:, cut:], v[:, cut:],
                                  bias[:, cut:], scale)
        m, l, acc = merge_stats(a, b)
        np.testing.assert_allclose(np.asarray(l), np.asarray(whole[1]),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(whole[2]),
                                   atol=1e-5, rtol=1e-5)


@settings(deadline=None, max_examples=10)
@given(b=st.integers(1, 4), m_loc=st.integers(2, 80),
       grp=st.sampled_from([1, 2, 4]), hkv=st.sampled_from([1, 2, 3]),
       prism=st.booleans(), seed=st.integers(0, 10_000))
def test_decode_kernel_property(b, m_loc, grp, hkv, prism, seed):
    """Property sweep: any (batch, cache length, GQA ratio, means?)
    draw — kernel ≡ oracle on live rows."""
    hq, hd = grp * hkv, 16
    mz = 6 if prism else 0
    case = make_case(b, m_loc, hq, hkv, hd, mz=mz, seed=seed)
    if prism:
        q, k, v, valid, pos, scale, kz, vz, gz = case
        owner = jnp.asarray(pos >= 0)
        want = prism_decode_attention(q, k, v, kz, vz, valid, gz,
                                      owner, (), scale)
        got = decode_attention(q, k, v, valid, (), scale, gz=gz, kz=kz,
                               vz=vz, owner=owner, mode="prism",
                               backend="pallas")
    else:
        q, k, v, valid, pos, scale = case
        want = flash_decode_combine(q, k, v, valid, (), scale)
        got = decode_attention(q, k, v, valid, (), scale,
                               backend="pallas")
    live = pos >= 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(want)[live],
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------
# backend dispatch
# ---------------------------------------------------------------------

def test_dispatch_rules():
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("jnp") == "jnp"
    # 'auto' resolves by platform; on the CPU CI image that is jnp
    auto = resolve_backend("auto")
    assert auto == ("pallas" if jax.default_backend() == "tpu" else "jnp")
    assert resolve_backend(None) in ("pallas", "jnp")
    assert use_pallas("pallas") and not use_pallas("jnp")
    with pytest.raises(ValueError):
        resolve_backend("cuda")
    # env override applies to 'auto'/None but never beats an explicit pick
    import os
    os.environ["PRISM_KERNEL_BACKEND"] = "pallas"
    try:
        assert resolve_backend("auto") == "pallas"
        assert resolve_backend(None) == "pallas"
        assert resolve_backend("jnp") == "jnp"
        os.environ["PRISM_KERNEL_BACKEND"] = "bogus"
        with pytest.raises(ValueError):
            resolve_backend("auto")
    finally:
        del os.environ["PRISM_KERNEL_BACKEND"]
    # interpret auto-detection: emulate everywhere but real TPU
    assert pallas_interpret() == (jax.default_backend() != "tpu")
    assert default_interpret(None) == pallas_interpret()
    assert default_interpret(True) is True
    assert default_interpret(False) is False


def test_ops_interpret_defaults_auto_detect():
    """The kernel wrappers no longer default to interpret=True: leaving
    ``interpret`` unset must resolve by platform (compiled on TPU) and
    still match the explicit-interpret result off-TPU."""
    from repro.kernels.ops import prism_attention_op
    from repro.kernels.segment_means import segment_means_op
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 24))
    got = segment_means_op(x, L=4)                  # interpret unset
    want = segment_means_op(x, L=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16))
    g = jnp.ones((8,), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    got = prism_attention_op(q, k, k, g, pos, pos, pos, causal=True)
    want = prism_attention_op(q, k, k, g, pos, pos, pos, causal=True,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6)


# ---------------------------------------------------------------------
# serve-step integration: backend routed through ServeHParams
# ---------------------------------------------------------------------

def test_serve_step_backend_equivalence():
    """Prefill + decode through make_serve_step with backend='pallas'
    (interpret on CPU) matches backend='jnp' — the whole hot path runs
    through the kernels, inside shard_map, and agrees with the oracle
    routing."""
    from repro.core.protocol import PrismConfig
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.runtime.serve import (ServeHParams, grow_cache,
                                     make_prefill_step, make_serve_step)
    tiny = ModelConfig(
        name="tiny-kb", arch_type="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=61,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(tiny, jax.random.PRNGKey(0))
    n0, cap = 8, 12
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, n0), 1,
                                tiny.vocab_size)
    outs = {}
    for backend in ("jnp", "pallas"):
        hp = ServeHParams(decode_mode="prism", ssm_chunk=8,
                          backend=backend)
        prism = PrismConfig(P=1, mode="prism")
        pre, lp, _, _ = make_prefill_step(tiny, mesh, params, prism,
                                          batch=2, n=n0, hp=hp)
        logits, cache = pre(params, {"tokens": prompt})
        step, ld, _, _ = make_serve_step(tiny, mesh, params, batch=2,
                                         cap=cap, prefill_len=n0, hp=hp)
        cache = grow_cache(cache, lp, ld)
        trace = [np.asarray(logits)]
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for g in range(2):
            pos = jnp.full((2,), n0 + g, jnp.int32)
            logits, cache = step(params, cache, tok, pos)
            trace.append(np.asarray(logits))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs[backend] = trace
    for a, b in zip(outs["jnp"], outs["pallas"]):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)

"""Distributed equivalence, via subprocess runners so the forced
host-device count never leaks into this process (unit tests and benches
must see the single real CPU device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


def run_child(script, timeout=1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    r = subprocess.run([sys.executable, os.path.join(HERE, script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    sys.stdout.write(r.stdout[-4000:])
    sys.stderr.write(r.stderr[-4000:])
    return r.returncode


@pytest.mark.slow
def test_sharded_train_equivalence():
    """shard_map PRISM/Voltage/SSM/MoE train step over 8 host devices
    == single-device simulated protocol (loss AND gradients)."""
    assert run_child("shard_equiv_runner.py") == 0


@pytest.mark.slow
def test_sharded_serve_equivalence():
    """prefill + incremental decode over 8 host devices == full forward."""
    assert run_child("serve_smoke_runner.py") == 0


@pytest.mark.slow
def test_engine_continuous_batching_equivalence():
    """6 staggered requests through a 4-slot sharded engine produce the
    same tokens as sequential serving, in exact AND prism modes."""
    assert run_child("engine_equiv_runner.py") == 0


@pytest.mark.slow
def test_roofline_collective_parser():
    """collective_bytes() parses a real compiled HLO and finds the PRISM
    all-gather; PRISM moves fewer collective bytes than Voltage on the
    same (model, mesh) — the paper's central claim, at HLO level."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.getcwd(), "src"))
import jax, jax.numpy as jnp
from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.runtime.train import make_train_step, TrainHParams
from repro.launch.roofline import collective_bytes

cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, pos="rope")
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = T.init(cfg, key)
hp = TrainHParams(remat=False, loss_chunks=2)
out = {}
for mode, cr in (("prism", 8.0), ("voltage", 1.0)):
    prism = PrismConfig(P=4, cr=cr, mode=mode)
    step, *_ = make_train_step(cfg, mesh, params, prism, hp)
    opt = jax.eval_shape(adamw_init, params)
    import jax as j
    psh = j.eval_shape(lambda: params)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    comp = step.lower(psh, opt, batch).compile()
    out[mode] = collective_bytes(comp.as_text())
print("prism", out["prism"]["total"], "voltage", out["voltage"]["total"])
assert out["prism"]["all-gather"] > 0
assert out["prism"]["total"] < out["voltage"]["total"], out
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=1200,
                       env=env, cwd=os.path.join(HERE, ".."))
    sys.stdout.write(r.stdout[-2000:])
    sys.stderr.write(r.stderr[-2000:])
    assert r.returncode == 0 and "OK" in r.stdout

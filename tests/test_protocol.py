"""Protocol bookkeeping: Alg. 1 partitioning, device views, and the
paper's communication accounting (§IV-C)."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.protocol import (
    PrismConfig, partition, partition_bounds, device_views,
    comm_elements_per_device_per_layer, comm_speedup, tensor_parallel_comm)


@settings(deadline=None, max_examples=40)
@given(n=st.integers(1, 100), p=st.integers(1, 8))
def test_partition_alg1(n, p):
    if n < p:
        with pytest.raises(ValueError):
            partition_bounds(n, p)
        return
    bounds = partition_bounds(n, p)
    # contiguous, covering, last takes remainder (Alg. 1)
    assert bounds[0][0] == 0
    assert sum(sz for _, sz in bounds) == n
    s = n // p
    assert all(sz == s for _, sz in bounds[:-1])
    assert bounds[-1][1] == s + n % p
    x = jnp.arange(n)[:, None] * jnp.ones((1, 3))
    parts = partition(x, p)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(q) for q in parts]), np.asarray(x))


def test_comm_accounting_matches_paper():
    """Voltage: (P-1)·N·D/P per device per layer; PRISM: (P-1)·L·D;
    tensor parallel: 4(P-1)·N·D/P (§II-B2, §IV-C)."""
    n, d = 4096, 768
    volt = comm_elements_per_device_per_layer(
        n, d, PrismConfig(P=4, mode="voltage"))
    assert volt == 3 * n * d / 4
    prism = comm_elements_per_device_per_layer(
        n, d, PrismConfig(P=4, L=16))
    assert prism == 3 * 16 * d
    assert tensor_parallel_comm(n, d, 4) == 4 * volt
    assert comm_elements_per_device_per_layer(
        n, d, PrismConfig(P=1)) == 0.0


def test_comm_speedup_vit_table4():
    """Reproduce the paper's ViT communication speed-up numbers:
    P=2, PDPLC=10 tokens of 99 -> 89.90%; P=3, 20 of 131 -> 84.73%."""
    d = 768
    # ViT: 197 tokens; P=2 partitions of ~99; L=10 means exchanged
    sp = comm_speedup(197, d, PrismConfig(P=2, L=10))
    assert abs(sp - (1 - 10 / 98.5) * 100) < 0.6     # ~89.85%
    # paper 'PDPLC=20' at P=3 means 20 RECEIVED tokens = (P-1)·L -> L=10
    sp3 = comm_speedup(197, d, PrismConfig(P=3, L=10))
    assert abs(sp3 - 84.73) < 1.0


@settings(deadline=None, max_examples=20)
@given(n=st.integers(8, 64), p=st.integers(2, 4), lf=st.floats(0.05, 1.0),
       mode=st.sampled_from(["prism", "duplicate"]))
def test_device_views_shapes(n, p, lf, mode):
    n -= n % p
    if n < p:
        return
    L = max(1, min(int(lf * n / p), n // p))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, n, 4)),
                    jnp.float32)
    cfg = PrismConfig(P=p, L=L, mode=mode)
    views = device_views(x, cfg)
    assert len(views) == p
    for dv in views:
        n_p = dv.x_p.shape[-2]
        m = dv.x_hat.shape[-2]
        if mode == "prism":
            assert m == n_p + (p - 1) * L
            assert dv.g is not None and dv.g.shape == (m,)
            assert (dv.g[:n_p] == 1).all()
            # repeat counts sum to the full sequence length
            assert int(dv.g.sum()) == n
        else:
            assert m == n                      # duplicated back to N
        assert dv.col_lo.shape == (m,)
        assert (dv.col_lo <= dv.col_hi).all()


def test_duplicate_mode_equals_prism_attention():
    """Table II machinery: 'duplicate' views + plain softmax must equal
    'prism' views + scaling softmax (the Eq. 12-15 rewrite)."""
    from repro.core.attention import prism_attention
    n, d, h, hd = 12, 8, 2, 4
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, n, d)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(size=(d, h * hd)) * 0.2,
                    jnp.float32)
    cfgp = PrismConfig(P=3, L=2, mode="prism")
    cfgd = PrismConfig(P=3, L=2, mode="duplicate")

    def proj(t):
        return (t @ w).reshape(*t.shape[:-1], h, hd)

    for dvp, dvd in zip(device_views(x, cfgp), device_views(x, cfgd)):
        a = prism_attention(proj(dvp.x_p), proj(dvp.x_hat),
                            proj(dvp.x_hat),
                            g=jnp.asarray(dvp.g, jnp.float32),
                            mask=dvp.mask(cfgp))
        b = prism_attention(proj(dvd.x_p), proj(dvd.x_hat),
                            proj(dvd.x_hat), mask=dvd.mask(cfgd))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_modes_validated():
    with pytest.raises(ValueError):
        PrismConfig(mode="bogus")
    with pytest.raises(ValueError):
        PrismConfig(P=0)

"""Model substrate tests: MoE dispatch, SSM chunked-scan oracle,
layer primitives, stacked-layout round trip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.context import FullContext
from repro.models.layers import rope, norm, norm_init, mlp, mlp_init
from repro.models.moe import (capacity, dispatch_indices, moe_init,
                              moe_apply, route)
from repro.models.ssm import chunked_linear_attention


class _Ident:
    def state_handoff(self, la, u):
        return jnp.zeros_like(u)

    def last_shard(self, x):
        return x


def naive_linear_recurrence(q, k, v, log_f, gate_i, normalize):
    """O(N²)-free scalar oracle: S_t = f_t S_{t-1} + i_t k_t v_tᵀ."""
    b, n, h, dk = q.shape
    dv = v.shape[-1]
    if normalize:
        v = np.concatenate([v, np.ones((*v.shape[:-1], 1), v.dtype)], -1)
        dv += 1
    s = np.zeros((b, h, dk, dv))
    ys = []
    for t in range(n):
        f = np.exp(log_f[:, t])[..., None, None]
        kv = np.einsum("bhd,bhv->bhdv", k[:, t], v[:, t]) \
            * gate_i[:, t][..., None, None]
        s = f * s + kv
        ys.append(np.einsum("bhd,bhdv->bhv", q[:, t], s))
    y = np.stack(ys, 1)
    if normalize:
        y, nrm = y[..., :-1], y[..., -1:]
        y = y / np.maximum(np.abs(nrm), 1.0)
    return y


@settings(deadline=None, max_examples=15)
@given(n=st.sampled_from([4, 8, 16]), chunk=st.sampled_from([2, 4, 8, 16]),
       normalize=st.booleans(), seed=st.integers(0, 10**6))
def test_chunked_linear_attention_vs_naive(n, chunk, normalize, seed):
    if chunk > n:
        chunk = n
    b, h, dk, dv = 2, 2, 4, 4
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, n, h, dk)).astype(np.float32)
    k = rng.normal(size=(b, n, h, dk)).astype(np.float32)
    v = rng.normal(size=(b, n, h, dv)).astype(np.float32)
    log_f = -np.abs(rng.normal(size=(b, n, h))).astype(np.float32)
    gi = rng.uniform(0.1, 1.0, size=(b, n, h)).astype(np.float32)
    got = chunked_linear_attention(
        *map(jnp.asarray, (q, k, v, log_f, gi)),
        chunk=chunk, ctx=_Ident(), normalize=normalize)
    want = naive_linear_recurrence(q, k, v, log_f, gi, normalize)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_chunked_final_state_matches_naive():
    b, n, h, dk, dv = 1, 12, 2, 3, 5
    rng = np.random.default_rng(0)
    q = rng.normal(size=(b, n, h, dk)).astype(np.float32)
    k = rng.normal(size=(b, n, h, dk)).astype(np.float32)
    v = rng.normal(size=(b, n, h, dv)).astype(np.float32)
    log_f = -np.abs(rng.normal(size=(b, n, h))).astype(np.float32)
    gi = rng.uniform(0.1, 1.0, size=(b, n, h)).astype(np.float32)
    _, state = chunked_linear_attention(
        *map(jnp.asarray, (q, k, v, log_f, gi)),
        chunk=4, ctx=_Ident(), normalize=False, return_state=True)
    s = np.zeros((b, h, dk, dv))
    for t in range(n):
        s = np.exp(log_f[:, t])[..., None, None] * s + \
            np.einsum("bhd,bhv->bhdv", k[:, t], v[:, t]) \
            * gi[:, t][..., None, None]
    np.testing.assert_allclose(np.asarray(state), s, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(t=st.integers(1, 64), k=st.integers(1, 4), e=st.sampled_from([4, 8]),
       seed=st.integers(0, 10**6))
def test_dispatch_indices_properties(t, k, e, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)))
    cap = capacity(t, k, e, 1.0)
    flat_e, slot, keep, token = map(np.asarray,
                                    dispatch_indices(idx, e, cap))
    # kept slots are unique per expert and < cap
    for ee in range(e):
        slots = slot[(flat_e == ee) & keep]
        assert len(set(slots.tolist())) == len(slots)
        assert (slots < cap).all()
    # tokens kept in FIFO order: a dropped token never precedes a kept one
    for ee in range(e):
        ranks = slot[flat_e == ee]
        kept = keep[flat_e == ee]
        assert (ranks[kept] < cap).all()


def test_moe_matches_dense_when_all_kept():
    """With capacity_factor high enough that nothing drops and top_k = E,
    the MoE output equals the softmax-weighted sum of all experts."""
    d, e, dff = 8, 4, 16
    cfg = ModelConfig(name="t", arch_type="moe", n_layers=1, d_model=d,
                      n_heads=1, n_kv_heads=1, d_ff=dff, vocab_size=16,
                      n_experts=e, top_k=e, expert_d_ff=dff,
                      capacity_factor=float(e * 2), mlp_kind="gelu")
    p = moe_init(jax.random.PRNGKey(0), d, e, dff, "gelu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, d))
    y, aux = moe_apply(p, x, cfg, FullContext())
    probs, idx, _ = route(p["router"], x.reshape(-1, d), e, e)
    want = np.zeros((6, d), np.float32)
    xf = np.asarray(x.reshape(-1, d))
    for t in range(6):
        for j in range(e):
            ee = int(idx[t, j])
            up = np.asarray(p["experts"]["up"]["w"][ee])
            dn = np.asarray(p["experts"]["down"]["w"][ee])
            h = np.asarray(jax.nn.gelu(xf[t] @ up)) @ dn
            want[t] += float(probs[t, j]) * h
    np.testing.assert_allclose(np.asarray(y).reshape(6, d), want,
                               atol=1e-4, rtol=1e-3)


def test_router_aux_loss_balanced_is_one():
    """Perfectly uniform routing gives aux ≈ 1 (Switch normalization)."""
    t, e = 1024, 8
    logits_w = jnp.zeros((4, e))
    p = {"w": logits_w}
    x = jnp.asarray(np.random.default_rng(0).normal(size=(t, 4)),
                    jnp.float32) * 0.0   # uniform router
    probs, idx, aux = route(p, x, 2, e)
    assert abs(float(aux) - 1.0) < 0.2


# ---------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------

def test_rope_preserves_inner_products_under_shift():
    """RoPE relative property: <R(q,i), R(k,j)> depends only on i-j."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def ip(i, j):
        qi = rope(q, jnp.asarray([i], jnp.float32))
        kj = rope(k, jnp.asarray([j], jnp.float32))
        return float((qi * kj).sum())
    assert abs(ip(3, 1) - ip(10, 8)) < 1e-4
    assert abs(ip(0, 0) - ip(7, 7)) < 1e-4


def test_norms():
    p = norm_init(8, "rmsnorm")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8)) * 10
    y = np.asarray(norm(p, x, "rmsnorm"))
    np.testing.assert_allclose((y ** 2).mean(-1), 1.0, rtol=1e-3)
    p2 = norm_init(8, "layernorm")
    y2 = np.asarray(norm(p2, x, "layernorm"))
    np.testing.assert_allclose(y2.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y2.std(-1), 1.0, rtol=1e-2)


def test_stacked_layout_roundtrip():
    """init stores layers stacked; iter_layers yields them in depth order
    with the right kinds."""
    cfg = ModelConfig(name="t", arch_type="hybrid", n_layers=7, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=8,
                      blocks=("mamba", "mamba", "shared_attn") * 2
                      + ("mamba",),
                      ssm_state=4, ssm_heads=2, pos="rope")
    u, n_units, n_tail = cfg.scan_split
    assert (u, n_units, n_tail) == (3, 2, 1)
    params = T.init(cfg, jax.random.PRNGKey(0))
    kinds = [k for k, _ in T.iter_layers(cfg, params)]
    assert kinds == list(cfg.block_kinds)
    logits, _ = T.forward(cfg, params,
                          jnp.zeros((1, 8), jnp.int32), chunk=4)
    assert logits.shape == (1, 8, 8)
    assert np.isfinite(np.asarray(logits)).all()

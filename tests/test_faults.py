"""Fault-tolerant serving tests (PR 8).

Four layers, mirroring the subsystem's split:

  * ``FaultSpec`` / ``FaultPlan`` / ``FaultInjector`` units: validation,
    per-kind stream independence, deterministic replay, scheduled
    ``at`` hits never shifting later Bernoulli decisions;
  * ``KVStore`` seams: an injected ``store_put_loss`` drops the put, an
    injected ``store_get_loss`` loses an existing entry at read time —
    both with exact byte accounting;
  * engine end-to-end on the 1x1 mesh: per-request deadlines cancel
    cleanly from every lifecycle state, a poisoned cache page
    quarantines exactly its own slot (neighbour tokens untouched),
    repeated lost restores re-prefill deterministically and
    ``max_restarts`` fails hard with everything reclaimed, and a full
    all-kinds chaos run stays token-identical to the clean run;
  * crash-consistent ``snapshot()`` / ``restore()``: a mid-flight
    engine journalled, torn down, and rebuilt resumes token-identically
    (the sharded 2x4 exact+prism version runs in
    ``tests/engine_equiv_runner.py``).
"""
import numpy as np
import jax
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.faults import (KINDS, FaultInjector, FaultPlan,
                                  FaultSpec)
from repro.runtime.offload import KVStore
from repro.serving import SamplingParams, ServingEngine


TINY = ModelConfig(
    name="tiny-serve", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _engine(params, mesh, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("max_cache", 24)
    kw.setdefault("prefix_cache", False)
    return ServingEngine(TINY, mesh, params, **kw)


def _submit_mix(eng, n=4, gen=6, **kw):
    rng = np.random.default_rng(3)
    for i in range(n):
        plen = int(rng.integers(3, 9))
        prompt = rng.integers(1, TINY.vocab_size, size=plen)
        eng.submit(prompt, max_new_tokens=gen,
                   sampling=SamplingParams(seed=i), **kw)


class _Clock:
    """Injectable logical clock: deadlines in these tests are measured
    in plain step units, not wall seconds."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# FaultSpec / FaultPlan / FaultInjector units
# --------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(p=1.5)
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(p=-0.1)
    assert FaultSpec().enabled is False
    assert FaultSpec(p=0.5).enabled and FaultSpec(at=(3,)).enabled
    assert FaultSpec(at=[1.0, 2]).at == (1, 2)   # coerced to int tuple


def test_fault_spec_shard_validation():
    with pytest.raises(ValueError, match="shard"):
        FaultSpec(shard=-1)
    assert FaultSpec(shard=2.0).shard == 2       # coerced to int
    assert FaultSpec().shard is None
    assert FaultSpec(at=(1,), shard=1).enabled


def test_fault_plan_lookup_and_chaos():
    plan = FaultPlan()
    assert not plan.any_enabled
    for kind in KINDS:
        assert plan.spec(kind) == FaultSpec()
    with pytest.raises(KeyError, match="unknown fault kind"):
        plan.spec("cosmic_ray")
    chaos = FaultPlan.chaos(7)
    assert chaos.seed == 7 and chaos.any_enabled
    assert all(chaos.spec(k).enabled for k in KINDS)
    # overrides replace the per-kind default
    quiet = FaultPlan.chaos(7, page_poison=FaultSpec())
    assert not quiet.spec("page_poison").enabled
    assert quiet.spec("tick_delay").enabled


def test_injector_deterministic_replay():
    plan = FaultPlan.chaos(42)
    a, b = FaultInjector(plan), FaultInjector(plan)
    for _ in range(300):
        for kind in KINDS:
            assert a.fire(kind) == b.fire(kind)
    assert a.injected == b.injected and a.ops == b.ops
    assert a.total_injected > 0
    assert a.stats()["seed"] == 42
    # a different seed gives a different schedule
    c, d = FaultInjector(FaultPlan.chaos(43)), FaultInjector(plan)
    seq_c = [c.fire("store_put_loss") for _ in range(200)]
    seq_d = [d.fire("store_put_loss") for _ in range(200)]
    assert seq_c != seq_d


def test_injector_streams_are_per_kind_independent():
    """Enabling / drawing one kind never perturbs another kind's
    schedule: the tick_delay decisions must be identical whether or not
    store_put_loss draws in between."""
    only_delay = FaultPlan(seed=9, tick_delay=FaultSpec(p=0.5))
    both = FaultPlan(seed=9, tick_delay=FaultSpec(p=0.5),
                     store_put_loss=FaultSpec(p=0.5))
    a, b = FaultInjector(only_delay), FaultInjector(both)
    for _ in range(200):
        b.fire("store_put_loss")         # interleaved draws on b only
        assert a.fire("tick_delay") == b.fire("tick_delay")


def test_injector_at_schedule_exact_and_stream_stable():
    plan = FaultPlan(seed=0, tick_delay=FaultSpec(at=(2, 5)))
    inj = FaultInjector(plan)
    fired = [inj.fire("tick_delay") for _ in range(8)]
    assert fired == [i in (2, 5) for i in range(8)]
    assert inj.injected["tick_delay"] == 2 and inj.ops["tick_delay"] == 8
    # a scheduled hit must not shift later Bernoulli decisions: with
    # p > 0 the stream draws on EVERY op, so the only index where the
    # two plans may differ is the scheduled one
    p_only = FaultInjector(FaultPlan(seed=1,
                                     tick_delay=FaultSpec(p=0.4)))
    p_and_at = FaultInjector(FaultPlan(seed=1,
                                       tick_delay=FaultSpec(p=0.4,
                                                            at=(3,))))
    for i in range(100):
        a, b = p_only.fire("tick_delay"), p_and_at.fire("tick_delay")
        if i == 3:
            assert b
        else:
            assert a == b


def test_injector_pick_deterministic():
    a = FaultInjector(FaultPlan.chaos(5))
    b = FaultInjector(FaultPlan.chaos(5))
    picks = [(a.pick("page_poison", 7), b.pick("page_poison", 7))
             for _ in range(100)]
    assert all(x == y for x, y in picks)
    assert all(0 <= x < 7 for x, _ in picks)


# --------------------------------------------------------------------------
# KVStore fault seams
# --------------------------------------------------------------------------

def test_store_put_loss_drops_the_put():
    inj = FaultInjector(FaultPlan(
        seed=0, store_put_loss=FaultSpec(at=(0,))))
    s = KVStore(injector=inj)
    assert not s.put("a", 3, None)           # injected loss
    assert "a" not in s and s.drops == 1 and s.bytes_used == 0
    assert s.put("b", 2, None)               # next op unaffected
    assert "b" in s and s.bytes_used == 2


def test_store_get_loss_tears_peek_and_pop():
    inj = FaultInjector(FaultPlan(
        seed=0, store_get_loss=FaultSpec(at=(0, 1))))
    s = KVStore(injector=inj)
    assert s.put("a", 3, None) and s.put("b", 2, None)
    assert s.peek("a") is None               # op 0: torn at read time
    assert "a" not in s and s.misses == 1
    assert s.pop("b") is None                # op 1: lost in flight
    assert "b" not in s and s.bytes_used == 0 and s.misses == 2
    assert s.put("c", 1, None)
    assert s.peek("c") is not None           # op 2: unscheduled, intact
    assert s.pop("c").n_pages == 1 and s.hits == 1


def test_store_get_bounded_retry_then_drop():
    """``KVStore.get`` retries a transient torn read (entry RETAINED
    across non-final losses) and only drops the entry when the final
    attempt loses too — the seam the restore path's bounded
    retry-with-backoff rides before downgrading to re-prefill."""
    inj = FaultInjector(FaultPlan(
        seed=0, store_get_loss=FaultSpec(at=(0,))))
    s = KVStore(injector=inj)
    assert s.put("a", 3, None)
    ent = s.get("a", retries=2)              # op 0 torn -> op 1 clean
    assert ent is not None and ent.n_pages == 3
    assert "a" in s and s.get_retries == 1
    assert s.stats()["get_retries"] == 1
    ent = s.get("a", retries=0, consume=True)   # op 2: clean pop
    assert ent is not None and "a" not in s and s.hits == 1

    # every attempt torn: the final loss keeps the old drop semantics
    inj2 = FaultInjector(FaultPlan(
        seed=0, store_get_loss=FaultSpec(at=(0, 1, 2))))
    s2 = KVStore(injector=inj2)
    assert s2.put("b", 2, None)
    assert s2.get("b", retries=2) is None
    assert "b" not in s2 and s2.bytes_used == 0
    assert s2.get_retries == 2 and s2.misses == 1

    # retries=0 is exactly the one-draw torn read
    inj3 = FaultInjector(FaultPlan(
        seed=0, store_get_loss=FaultSpec(at=(0,))))
    s3 = KVStore(injector=inj3)
    assert s3.put("c", 1, None)
    assert s3.get("c") is None and "c" not in s3
    assert s3.get_retries == 0


# --------------------------------------------------------------------------
# per-request deadlines
# --------------------------------------------------------------------------

def test_deadline_validation():
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh())
    with pytest.raises(ValueError, match="deadline"):
        eng.submit((1, 2, 3), max_new_tokens=2, arrival=5.0, deadline=5.0)
    with pytest.raises(ValueError, match="deadline"):
        eng.submit((1, 2, 3), max_new_tokens=2, arrival=5.0, deadline=1.0)


def test_deadline_generous_never_fires():
    clk = _Clock()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), clock=clk)
    _submit_mix(eng, n=2, deadline=1e9)
    for _ in range(200):
        clk.t += 1.0
        if eng.step() == "idle" and not eng._sched.has_work:
            break
    assert eng.stats.completed == 2 and eng.stats.deadline_miss == 0
    assert not eng.failed()


def test_deadline_expiry_across_lifecycle_states():
    """One engine, four doomed requests in four different states when
    the clock passes their deadline — active (decoding), spilled on the
    resume queue, queued fresh, and suspended.  Every cancellation
    reclaims exactly what that state holds: pages + state row + slot,
    store bytes, or just the queue position."""
    clk = _Clock()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True, n_slots=3, clock=clk)
    rng = np.random.default_rng(0)
    p = lambda n: rng.integers(1, TINY.vocab_size, size=n)
    a = eng.submit(p(6), max_new_tokens=8, deadline=500.0, priority=1)
    b = eng.submit(p(6), max_new_tokens=8, deadline=500.0)
    d = eng.submit(p(5), max_new_tokens=8, deadline=500.0)
    for _ in range(100):
        clk.t += 1.0
        eng.step()
        sts = [eng._find_active(r) for r in (a, b, d)]
        if all(st is not None and st.generated for st in sts):
            break
    else:
        raise AssertionError("never reached steady decode")
    assert eng.preempt(b)                       # b: spilled, resume-parked
    assert eng.suspend(d)                       # d: suspended
    c = eng.submit(p(4), max_new_tokens=4, deadline=500.0)   # c: queued
    assert b in eng.kv_store and d in eng.kv_store

    clk.t = 500.0                               # every deadline passes
    assert eng.step() == "idle"
    assert eng.stats.deadline_miss == 4
    assert eng.stats.deadline_miss_by_class == {0: 3, 1: 1}
    assert eng.failed() == {r: "deadline" for r in (a, b, c, d)}
    assert not eng._sched.has_work and not eng._suspended
    # zero leak: pages, state rows, store bytes, slots all reclaimed
    kv = eng.kv_cache
    kv.check()
    assert not kv.slot_pages and not kv.slot_state
    assert kv.table.free_pages == kv.paging.n_pages
    assert len(eng.kv_store) == 0 and eng.kv_store.bytes_used == 0
    assert sorted(eng._sched.free_slots) == [0, 1, 2]
    assert eng.run() == {}                      # nothing left to serve
    assert eng.stats.deadline_miss_by_class == {0: 3, 1: 1}
    s = eng.stats.summary()
    assert s["deadline_miss"] == 4
    assert s["deadline_miss_by_class"] == {"0": 3, "1": 1}


def test_deadline_mixed_with_survivors():
    """A doomed request expiring mid-decode never perturbs the tokens
    of a surviving neighbour."""
    clk = _Clock()
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh, clock=_Clock())
    oracle.submit(tuple(range(1, 7)), max_new_tokens=6,
                  sampling=SamplingParams(seed=1))
    want = oracle.run()[0]

    eng = _engine(params, mesh, clock=clk)
    doomed = eng.submit(tuple(range(2, 8)), max_new_tokens=18,
                        deadline=4.0, sampling=SamplingParams(seed=0))
    keep = eng.submit(tuple(range(1, 7)), max_new_tokens=6,
                      sampling=SamplingParams(seed=1))
    for _ in range(100):
        clk.t += 1.0
        if eng.step() == "idle" and not eng._sched.has_work:
            break
    out = eng.results()
    assert doomed not in out
    assert out[keep] == want
    assert eng.failed() == {doomed: "deadline"}
    assert eng.stats.deadline_miss == 1


# --------------------------------------------------------------------------
# NaN/inf guard + quarantine
# --------------------------------------------------------------------------

def test_poisoned_page_quarantines_only_that_slot():
    """NaN-poison one request's private cache page mid-decode: the
    isfinite guard must quarantine exactly that slot (re-prefill in
    place, seeded RNG re-armed) and the neighbour must finish with
    tokens UNTOUCHED — both end token-identical to the clean oracle."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh)
    _submit_mix(eng, n=2)
    poisoned = False
    for _ in range(400):
        if not eng._sched.has_work and not eng._pending:
            break
        st = eng._find_active(0)
        if (not poisoned and st is not None and not st.prefilling
                and len(st.generated) >= 2 and not st.finished()):
            kv = eng.kv_cache
            kv.poison_page(kv.slot_pages[st.slot][0])
            poisoned = True
        eng.step()
    assert poisoned
    assert eng.stats.quarantined == 1 and eng.stats.restarts == 1
    assert eng.results() == want          # rid 0 reran, rid 1 untouched
    assert eng._results[0].restarts == 1
    assert eng._results[1].restarts == 0
    assert not eng.failed()
    kv = eng.kv_cache
    kv.check()
    assert kv.table.free_pages == kv.paging.n_pages


def test_quarantine_max_restarts_fails_hard():
    """A slot that keeps producing NaNs exhausts ``max_restarts`` and
    fails hard: pages scrubbed + reclaimed, the request lands in
    ``failed()``, and the neighbour still matches the oracle."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh, max_restarts=1)
    _submit_mix(eng, n=2)
    for _ in range(400):
        if not eng._sched.has_work and not eng._pending:
            break
        st = eng._find_active(0)
        if (st is not None and not st.prefilling
                and not st.finished()):
            kv = eng.kv_cache
            kv.poison_page(kv.slot_pages[st.slot][0])   # every decode tick
        eng.step()
    assert eng.failed() == {0: "max_restarts"}
    assert eng.stats.quarantined == 2          # one reset + one fail-hard
    assert eng.stats.restarts == 1
    assert eng.stats.failed_requests == 1
    out = eng.results()
    assert 0 not in out and out[1] == want[1]
    kv = eng.kv_cache
    kv.check()
    assert kv.table.free_pages == kv.paging.n_pages
    assert not kv.slot_pages and not kv.slot_state


# --------------------------------------------------------------------------
# repeated lost restores (satellite: reset_for_refill under restarts)
# --------------------------------------------------------------------------

def test_three_lost_restores_still_emit_oracle_tokens():
    """Three consecutive lost restores (zero-capacity store) re-seed
    the sampler RNG deterministically each time and the request still
    finishes with EXACTLY the oracle's tokens (default max_restarts=3
    permits all three resets)."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True)
    eng._store = KVStore(capacity_bytes=0)       # every spill is lost
    _submit_mix(eng, n=2)
    times = 0
    for _ in range(600):
        if not eng._sched.has_work and not eng._pending:
            break
        st = eng._find_active(0)
        if (times < 3 and st is not None and not st.prefilling
                and len(st.generated) >= 1 and not st.finished()):
            assert eng.preempt(0)
            times += 1
        eng.step()
    assert times == 3
    assert eng.results() == want
    assert eng.stats.restore_misses == 3 and eng.stats.restore_hits == 0
    assert eng.stats.restarts == 3
    assert eng._results[0].restarts == 3
    assert not eng.failed()
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages


def test_max_restarts_exceeded_fails_cleanly():
    """With max_restarts=2 the third lost restore gives up: the request
    fails (never hangs, never blocks the admission queue), its pages
    and store bytes are reclaimed, and the neighbour is untouched."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True, max_restarts=2)
    eng._store = KVStore(capacity_bytes=0)
    _submit_mix(eng, n=2)
    times = 0
    for _ in range(600):
        if not eng._sched.has_work and not eng._pending:
            break
        st = eng._find_active(0)
        if (times < 3 and st is not None and not st.prefilling
                and len(st.generated) >= 1 and not st.finished()):
            assert eng.preempt(0)
            times += 1
        eng.step()
    assert times == 3
    assert eng.failed() == {0: "max_restarts"}
    assert eng.stats.failed_requests == 1
    assert eng.stats.restarts == 2               # budget fully used first
    out = eng.results()
    assert 0 not in out and out[1] == want[1]
    kv = eng.kv_cache
    kv.check()
    assert kv.table.free_pages == kv.paging.n_pages
    assert not kv.slot_pages and not kv.slot_state
    assert len(eng.kv_store) == 0 and eng.kv_store.bytes_used == 0
    assert sorted(eng._sched.free_slots) == list(range(4))


def test_restore_retries_transient_store_loss():
    """A transient ``store_get_loss`` during restore is retried away
    (``EngineConfig.restore_retries``) instead of downgrading to
    re-prefill — and with retries off, the SAME plan downgrades."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    def drive(**kw):
        plan = FaultPlan(store_get_loss=FaultSpec(at=(0,)))
        eng = _engine(params, mesh, offload=True, faults=plan, **kw)
        _submit_mix(eng, n=2)
        pre = False
        for _ in range(600):
            if not eng._sched.has_work and not eng._pending:
                break
            st = eng._find_active(0)
            if (not pre and st is not None and not st.prefilling
                    and len(st.generated) >= 1 and not st.finished()):
                assert eng.preempt(0)
                pre = True
            eng.step()
        assert pre and eng.results() == want and not eng.failed()
        return eng

    eng = drive()                            # default restore_retries=2
    assert eng.stats.restore_hits == 1 and eng.stats.restore_misses == 0
    assert eng.stats.restarts == 0
    assert eng.stats.store_get_retries >= 1
    assert eng.stats.summary()["store_get_retries"] >= 1

    eng0 = drive(restore_retries=0)          # same plan, no retry budget
    assert eng0.stats.restore_misses == 1 and eng0.stats.restarts == 1
    assert eng0.stats.store_get_retries == 0


# --------------------------------------------------------------------------
# packed-path isfinite guard (satellite: forced non-finite decode row)
# --------------------------------------------------------------------------

def test_packed_nonfinite_row_quarantines_exactly_one_slot():
    """Force a non-finite decode row out of a PACKED tick (mixed
    prefill + decode) and assert the packed-path isfinite guard
    quarantines exactly that one slot: the poisoned request re-prefills
    and recovers, the mid-prefill neighbour is untouched, and both end
    token-identical to the clean oracle."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    pA, pB = tuple(range(1, 7)), tuple(range(2, 8))
    oracle = _engine(params, mesh)
    oracle.submit(pA, max_new_tokens=6, sampling=SamplingParams(seed=0))
    oracle.submit(pB, max_new_tokens=6, sampling=SamplingParams(seed=1))
    want = oracle.run()

    eng = _engine(params, mesh)
    eng.submit(pA, max_new_tokens=6, sampling=SamplingParams(seed=0))
    for _ in range(100):                     # drive A into steady decode
        eng.step()
        st = eng._find_active(0)
        if (st is not None and not st.prefilling
                and len(st.generated) >= 1 and not st.finished()):
            break
    else:
        raise AssertionError("request 0 never reached decode")
    # B admits this tick -> mixed packed tick; decode rows pack first,
    # so row 0 is A's decode row
    eng.submit(pB, max_new_tokens=6, sampling=SamplingParams(seed=1))
    real = eng._packed

    def nan_row0(*a, **k):
        logits, storage = real(*a, **k)
        return logits.at[0].set(float("nan")), storage

    eng._packed = nan_row0
    assert eng.step() == "packed"
    eng._packed = real
    assert eng.stats.quarantined == 1        # exactly one slot
    assert eng.stats.restarts == 1
    eng.run()
    assert eng.results() == want and not eng.failed()
    assert eng._results[0].restarts == 1
    assert eng._results[1].restarts == 0


# --------------------------------------------------------------------------
# shard loss: degraded window + standby replicas (1x1 total loss; the
# 2x4 exact+prism cells run in engine_equiv_runner.py)
# --------------------------------------------------------------------------

def test_shard_loss_degraded_window_recovers_token_identical():
    """Kill the (only) sequence shard mid-decode: the engine serves a
    bounded degraded window through the Segment-Means standby replicas
    (finite tokens, no failures), then recovers via the deterministic
    re-prefill and finishes token-identical to the clean oracle, with
    the loss visible in the stats and the drained engine leak-free."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=3)
    want = oracle.run()

    plan = FaultPlan(shard_loss=FaultSpec(at=(6,), shard=0))
    eng = _engine(params, mesh, faults=plan)
    assert eng._replica is not None          # standby layer armed
    _submit_mix(eng, n=3)
    kinds = []
    for _ in range(600):
        if not eng._sched.has_work and not eng._pending:
            break
        kinds.append(eng.step())
    assert "degraded" in kinds and "recovered" in kinds
    assert eng.results() == want and not eng.failed()
    s = eng.stats.summary()
    assert s["shard_lost"] == 1 and s["degraded_ticks"] >= 1
    assert s["faults_by_kind"]["shard_loss"] == 1
    assert eng._replica.stats()["captures"] >= 1
    kv = eng.kv_cache
    kv.check()
    assert not kv.slot_pages and not kv.slot_state
    assert kv.table.free_pages == kv.paging.n_pages
    assert sorted(eng._sched.free_slots) == list(range(4))


def test_shard_loss_snapshot_refused_while_degraded():
    """The snapshot gather reads every shard; while one is lost the
    journal would be torn — snapshot() must refuse until recovery."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    plan = FaultPlan(shard_loss=FaultSpec(at=(4,), shard=0),
                     # hold the degraded window open long enough to
                     # catch it mid-flight
                     seed=0)
    eng = _engine(params, _mesh(), faults=plan, degraded_grace=50)
    _submit_mix(eng, n=2)
    for _ in range(200):
        if eng.step() == "degraded":
            break
    else:
        raise AssertionError("never entered the degraded window")
    with pytest.raises(ValueError, match="degraded"):
        eng.snapshot()
    for _ in range(600):                     # drain through recovery
        if not eng._sched.has_work and not eng._pending:
            break
        eng.step()
    assert eng.snapshot() is not None        # recovered: journal fine


# --------------------------------------------------------------------------
# all-kinds chaos, engine end-to-end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_engine_token_identical_and_leak_free(seed):
    """The full chaos plan (store loss, page poisoning, admission
    stalls, tick delays) plus forced preemptions: every request that
    completes is token-identical to the clean run, every request is
    accounted for, and the drained engine audits leak-free."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=4)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True,
                  faults=FaultPlan.chaos(seed), max_restarts=8)
    _submit_mix(eng, n=4)
    hit = set()
    for _ in range(3000):
        if not eng._sched.has_work and not eng._pending:
            break
        eng.step()
        for st in list(eng._sched.active.values()):
            rid = st.req.rid
            if (rid not in hit and not st.prefilling
                    and len(st.generated) >= 1 and not st.finished()):
                assert eng.preempt(rid)
                hit.add(rid)
    else:
        raise AssertionError("chaos run did not drain")
    out, failed = eng.results(), eng.failed()
    assert set(out) | set(failed) == set(range(4))
    assert not (set(out) & set(failed))
    for rid, toks in out.items():
        assert toks == want[rid], f"rid {rid} diverged under faults"
    assert eng._injector.total_injected > 0
    assert eng.stats.faults_injected == eng._injector.total_injected
    kv = eng.kv_cache
    kv.check()
    assert not kv.slot_pages and not kv.slot_state
    assert kv.table.free_pages == kv.paging.n_pages
    assert len(eng.kv_store) == 0 and eng.kv_store.bytes_used == 0
    assert sorted(eng._sched.free_slots) == list(range(4))


# --------------------------------------------------------------------------
# crash-consistent snapshot / restore (1x1; the 2x4 exact+prism cells
# run in engine_equiv_runner.py)
# --------------------------------------------------------------------------

def test_snapshot_restore_mid_flight_token_identical():
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    eng = _engine(params, mesh, offload=True, n_slots=2)
    _submit_mix(eng, n=3)                        # 2 active + 1 queued
    for _ in range(100):
        eng.step()
        if any(st.generated for st in eng._sched.active.values()):
            break
    assert eng.preempt(0)                        # >= 1 spilled at the cut
    snap = eng.snapshot()
    ref = eng.run()                              # snapshot is non-destructive
    assert sorted(ref) == [0, 1, 2]

    eng2 = _engine(params, mesh, offload=True, n_slots=2)
    eng2.restore(snap)
    assert 0 in eng2.kv_store                    # spilled entry journalled
    out2 = eng2.run()
    assert out2 == ref                           # token-identical resume
    assert len(eng2.kv_store) == 0
    eng2.kv_cache.check()

    # the journal is re-restorable: a third engine from the SAME snap
    eng3 = _engine(params, mesh, offload=True, n_slots=2)
    eng3.restore(snap)
    assert eng3.run() == ref


def test_snapshot_restore_validation():
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    dense = _engine(params, mesh, paged=False, prefix_cache=False)
    with pytest.raises(ValueError, match="paged"):
        dense.snapshot()

    eng = _engine(params, mesh, offload=True)
    _submit_mix(eng, n=2)
    eng.step()
    snap = eng.snapshot()
    with pytest.raises(ValueError, match="fresh"):
        eng.restore(snap)                        # target must be fresh
    eng.run()

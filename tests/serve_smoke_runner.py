"""Standalone runner: prefill + incremental decode must match the
single-device full forward's last-token logits (exact mode), and be
plausible in prism mode (approximate by design).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serve import (ServeHParams, make_prefill_step,
                                 make_serve_step, make_layout, grow_cache)


def check(name, cfg, mode, *, atol, batch=8, n=32, gen=4, backend="auto"):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    total = n + gen
    tokens = jax.random.randint(key, (batch, total), 0, cfg.vocab_size)

    name = f"{name}+{backend}" if backend != "auto" else name
    hp = ServeHParams(decode_mode="exact" if mode == "tp" else mode,
                      decode_tp=(mode == "tp"), ssm_chunk=8, means_cr=4.0,
                      backend=backend)
    prism = PrismConfig(P=4, mode="prism" if mode == "prism" else "voltage")
    prefill, lay_p, _, _ = make_prefill_step(
        cfg, mesh, params, prism, batch=batch, n=n, hp=hp)
    logits_pre, cache = prefill(params, {"tokens": tokens[:, :n]})

    # prefill last-token logits vs full forward over the first n tokens
    ref_n, _ = T.forward(cfg, params, tokens[:, :n], chunk=8)
    if mode in ("exact", "tp"):
        got = np.asarray(jax.device_get(logits_pre))
        ref = np.asarray(ref_n[:, -1])
        err = np.abs(got - ref).max() / max(1e-6, np.abs(ref).max())
        print(f"[{name}/{mode}] prefill rel-err={err:.2e} "
              f"{'OK' if err < atol else 'FAIL'}")
        if err >= atol:
            return False

    cap = n + ((gen + 3) // 4) * 4
    step, lay_d, _, _ = make_serve_step(cfg, mesh, params, batch=batch,
                                        cap=cap, prefill_len=n, hp=hp)
    cache = grow_cache(jax.device_get(cache) and cache, lay_p, lay_d)

    ok = True
    for g in range(gen):
        pos = jnp.full((batch,), n + g, jnp.int32)
        logits_dec, cache = step(params, cache, tokens[:, n + g], pos)
        if mode in ("exact", "tp"):
            ref_g, _ = T.forward(cfg, params, tokens[:, :n + g + 1], chunk=1)
            ref = np.asarray(ref_g[:, -1])
            got = np.asarray(jax.device_get(logits_dec))
            err = np.abs(got - ref).max() / max(1e-6, np.abs(ref).max())
            step_ok = err < atol
            ok &= step_ok
            print(f"[{name}/{mode}] decode step {g} rel-err={err:.2e} "
                  f"{'OK' if step_ok else 'FAIL'}")
        else:
            got = np.asarray(jax.device_get(logits_dec))
            step_ok = np.isfinite(got).all()
            ok &= step_ok
            print(f"[{name}/{mode}] decode step {g} finite "
                  f"{'OK' if step_ok else 'FAIL'}")
    return ok


def main():
    ok = True
    dense = ModelConfig(
        name="tiny-dense", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)
    ok &= check("dense", dense, "exact", atol=5e-5)
    ok &= check("dense", dense, "prism", atol=0.5)
    ok &= check("dense", dense, "tp", atol=5e-5)
    # forced-Pallas (interpret off-TPU): the kernels on a real 4-way
    # sequence-sharded mesh — exact vs the full-forward oracle proves
    # the cross-shard stat combine over kernel stats; prism exercises
    # the in-kernel means columns with real per-shard gz
    ok &= check("dense", dense, "exact", atol=5e-5, backend="pallas")
    ok &= check("dense", dense, "prism", atol=0.5, backend="pallas")

    window = ModelConfig(
        name="tiny-window", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=64,
        blocks=("attn_local", "attn"), window=8, mlp_kind="geglu",
        norm_kind="rmsnorm", pos="rope", qk_norm=True, tie_embeddings=True)
    ok &= check("window", window, "exact", atol=5e-5)

    ssm = ModelConfig(
        name="tiny-xlstm", arch_type="ssm", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
        blocks=("mlstm", "slstm"), norm_kind="rmsnorm", pos="none",
        ssm_heads=2, tie_embeddings=False)
    ok &= check("ssm", ssm, "exact", atol=5e-4)

    hybrid = ModelConfig(
        name="tiny-zamba", arch_type="hybrid", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64,
        blocks=("mamba", "shared_attn", "mamba"), norm_kind="rmsnorm",
        pos="rope", ssm_state=8, ssm_heads=4, shared_attn_every=2,
        tie_embeddings=False)
    ok &= check("hybrid", hybrid, "exact", atol=5e-4)

    moe = ModelConfig(
        name="tiny-moe", arch_type="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=64, vocab_size=64,
        blocks=("moe", "moe"), mlp_kind="swiglu", norm_kind="rmsnorm",
        pos="rope", n_experts=4, top_k=2, expert_d_ff=64,
        capacity_factor=8.0, tie_embeddings=False)
    ok &= check("moe", moe, "exact", atol=5e-4)
    ok &= check("moe", moe, "tp", atol=5e-4)

    print("ALL OK" if ok else "SERVE FAILURES")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

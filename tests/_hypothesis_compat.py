"""Use hypothesis when installed; otherwise a deterministic stand-in.

The serving image doesn't ship hypothesis, and the property tests here
assert exact identities the whole repo rests on — skipping them
wholesale would blind the suite.  The fallback runs each @given test
over a fixed number of seeded random draws from the declared
strategies: weaker than hypothesis's shrinking search, but the same
assertions over the same input space, reproducibly.
"""
try:
    from hypothesis import given, settings, strategies as st   # noqa: F401
except ModuleNotFoundError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mimics hypothesis.strategies
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: r.randint(lo, hi))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda r: r.uniform(lo, hi))

        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            return _Strategy(lambda r: r.choice(xs))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

    def settings(**_kw):
        return lambda f: f

    def given(**strategies):
        def deco(f):
            # no functools.wraps: pytest must see a zero-arg function,
            # not the wrapped parameter list (it would read the params
            # as fixtures)
            def run():
                r = random.Random(0)
                for _ in range(10):
                    f(**{k: s.draw(r) for k, s in strategies.items()})
            run.__name__ = f.__name__
            run.__doc__ = f.__doc__
            return run
        return deco

"""Token-packed unified serving-step tests on the single real CPU
device (mesh 1x1; the sharded versions run via
tests/engine_equiv_runner.py):

* the packed program writes the SAME cache the chunk program writes
  (mixed slots, ragged offsets, dead tail entries);
* packed serving is token-identical to sequential serving and to the
  chunked oracle, including ragged token budgets (T_budget not a
  multiple of the live token count) and prompt lengths off the budget
  boundary;
* ADVERSARIAL cross-request isolation: two requests with IDENTICAL
  prompts packed into one tick must not leak softmax stats into each
  other — each must generate exactly what it generates alone;
* prism Segment-Means state written by packed prefill is pinned
  against the PR-4 UNPADDED monolithic reference (gz/vz/zsum);
* the engine's compiled-program cache keeps the number of traces
  bounded while ticks alternate packed <-> decode (jit-lowering
  counter), and the chunk path reports its real-vs-padded token split.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serve import (ServeHParams, init_cache,
                                 make_chunk_prefill_step,
                                 make_packed_step, make_prefill_step,
                                 trace_counts)
from repro.serving import ServingEngine


TINY = ModelConfig(
    name="tiny-serve", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _oracle(params, prompt, n_gen):
    seq = list(prompt)
    for _ in range(n_gen):
        logits, _ = T.forward(TINY, params, jnp.asarray([seq]), chunk=8)
        seq.append(int(np.argmax(np.asarray(logits[0, -1]))))
    return seq[len(prompt):]


def test_packed_program_writes_same_cache_as_chunk():
    """Driving the packed program with a flat mixed-slot token batch
    (ragged offsets, dead tail) lays down the same K/V the chunk
    program does."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    n0, cap, B, TB = 8, 16, 4, 7
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, TINY.vocab_size, size=n0)
    p3 = rng.integers(1, TINY.vocab_size, size=5)

    chunk, lay, _ = make_chunk_prefill_step(
        TINY, mesh, params, batch=B, cap=cap, prefill_len=n0,
        chunk_len=n0, hp=hp)
    ref = init_cache(TINY, lay, B, hp)
    toks = np.zeros((B, n0), np.int32)
    off = np.full(B, -1, np.int32)
    nreal = np.zeros(B, np.int32)
    toks[1], off[1], nreal[1] = p1, 0, n0
    toks[3, :5], off[3], nreal[3] = p3, 0, 5
    ref = chunk(params, ref, jnp.asarray(toks), jnp.asarray(off),
                jnp.asarray(nreal))

    packed, lp, _, _ = make_packed_step(
        TINY, mesh, params, batch=B, cap=cap, prefill_len=n0,
        token_budget=TB, hp=hp)
    assert lp == lay
    got = init_cache(TINY, lay, B, hp)
    # three ragged ticks: 7 + 5 + 1 tokens (last tick mostly dead)
    work = ([(1, i) for i in range(n0)] + [(3, i) for i in range(5)])
    offs = {1: 0, 3: 0}
    while work:
        take, work = work[:TB], work[TB:]
        tok = np.zeros(TB, np.int32)
        slot = np.full(TB, -1, np.int32)
        pos = np.full(TB, -1, np.int32)
        offv = np.full(TB, -1, np.int32)
        pre = np.zeros(TB, np.int32)
        starts = {}
        for i, (s, p) in enumerate(take):
            tok[i] = (p1 if s == 1 else p3)[p]
            slot[i], pos[i], pre[i] = s, p, 1
            starts.setdefault(s, p)
        for i, (s, p) in enumerate(take):
            offv[i] = starts[s]
        _, got = packed(params, got, jnp.asarray(tok), jnp.asarray(slot),
                        jnp.asarray(pos), jnp.asarray(offv),
                        jnp.asarray(pre))
    for u in range(2):
        for key in ("k", "v"):
            a = np.asarray(ref["scan"][0][key][u])
            b = np.asarray(got["scan"][0][key][u])
            assert np.abs(a[1, :n0] - b[1, :n0]).max() < 1e-5, (u, key)
            assert np.abs(a[3, :5] - b[3, :5]).max() < 1e-5, (u, key)


@pytest.mark.parametrize("mode", ["exact", "prism"])
def test_packed_matches_sequential_and_chunked(mode):
    """Concurrent packed serving == sequential serving == the chunked
    oracle, token for token, in both decode modes."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode=mode, ssm_chunk=8, means_cr=4.0)
    kw = dict(n_slots=3, prefill_len=8, max_cache=24, hp=hp)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, TINY.vocab_size,
                            size=int(rng.integers(4, 9))).tolist()
               for _ in range(5)]

    def drive(engine):
        for p in prompts[:3]:
            engine.submit(p, max_new_tokens=6)
        for _ in range(3):
            engine.step()
        for p in prompts[3:]:
            engine.submit(p, max_new_tokens=6)
        return engine.run()

    packed = drive(ServingEngine(TINY, mesh, params, token_budget=7,
                                 **kw))
    chunked = drive(ServingEngine(TINY, mesh, params, chunk_len=4,
                                  prefill_mode="chunked", **kw))
    for i, p in enumerate(prompts):
        seq = ServingEngine(TINY, mesh, params, token_budget=7, **kw)
        rid = seq.submit(p, max_new_tokens=6)
        want = seq.run()[rid]
        assert packed[i] == want, (mode, i)
        assert packed[i] == chunked[i], (mode, i)


def test_packed_cross_request_isolation_identical_prompts():
    """ADVERSARIAL: two requests with IDENTICAL prompts admitted
    together land in the same packed tick; a stats leak between their
    (identical-content, different-slot) tokens would shift both away
    from the solo generation.  Both must match the solo run exactly —
    and so must a third, different, request sharing the ticks."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    kw = dict(n_slots=3, prefill_len=8, max_cache=24, token_budget=9)
    prompt = [7, 19, 3, 42, 11, 23]
    other = [5, 50, 2]

    eng = ServingEngine(TINY, mesh, params, **kw)
    r0 = eng.submit(prompt, max_new_tokens=6)
    r1 = eng.submit(prompt, max_new_tokens=6)
    r2 = eng.submit(other, max_new_tokens=6)
    got = eng.run()

    solo = ServingEngine(TINY, mesh, params, **kw)
    rid = solo.submit(prompt, max_new_tokens=6)
    want = solo.run()[rid]
    assert got[r0] == want
    assert got[r1] == want
    assert got[r2] == _oracle(params, other, 6)
    # all three prompts (6+6+3 = 15 tokens > budget 9) really were
    # packed concurrently
    assert eng.stats.packed_ticks >= 2
    assert eng.stats.packed_prefill_tokens == 15


def test_packed_ragged_budgets_match_oracle():
    """T_budget values that never divide the live token count (prompt
    lengths at/off the budget boundary, budget smaller than a prompt,
    mostly-dead final ticks) all match the teacher-forced oracle."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    for tb, plen in ((2, 5), (3, 3), (5, 8), (7, 4)):
        prompt = rng.integers(1, TINY.vocab_size, size=plen).tolist()
        eng = ServingEngine(TINY, mesh, params, n_slots=2,
                            prefill_len=8, max_cache=24,
                            token_budget=tb)
        rid = eng.submit(prompt, max_new_tokens=4)
        got = eng.run()[rid]
        assert got == _oracle(params, prompt, 4), (tb, plen)
        s = eng.stats.summary()
        # prefill spreads over ceil(plen / (tb - decodes)) ticks; with
        # nothing decoding the whole budget is prompt tokens
        assert s["packed_prefill_tokens"] == plen
        assert s["packed_ticks"] >= -(-plen // tb)


def test_packed_prism_means_pinned_against_unpadded_reference():
    """A short prompt whose prefill arrives PACKED (split across ragged
    ticks) produces the same Segment-Means state (gz / vz / zsum) as
    the PR-4 unpadded monolithic reference — real columns only, no pad
    contamination."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    n0, cap, plen = 8, 16, 6
    hp = ServeHParams(decode_mode="prism", ssm_chunk=8, means_cr=8.0)
    prompt = [7, 19, 3, 42, 11, 23]

    # paged=False: this test reads the DENSE cache leaves by slot row
    # (the paged prism engine keeps this state in the pooled state rows;
    # its token-level equivalence runs via tests/engine_equiv_runner.py)
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=n0,
                        max_cache=cap, hp=hp, token_budget=4,
                        paged=False)
    assert eng.layout.L == 1
    eng.submit(prompt, max_new_tokens=1)
    eng.run()
    assert eng.stats.packed_ticks >= 2   # 6 prompt tokens over budget 4
    cache = eng.kv_cache.storage

    prism = PrismConfig(P=1, cr=8.0, mode="voltage")
    pre, _, _, _ = make_prefill_step(TINY, mesh, params, prism,
                                     batch=1, n=plen, hp=hp)
    _, ref = pre(params, {"tokens": jnp.asarray(np.asarray(
        prompt, np.int32)[None])})

    for u in range(2):
        gz = np.asarray(cache["scan"][0]["gz"][u, 0])
        assert gz.tolist() == [float(plen)], gz   # real count, NOT n0
        for key in ("vz", "zsum"):
            a = np.asarray(ref["scan"][0][key][u, 0])
            b = np.asarray(cache["scan"][0][key][u, 0])
            scale = max(np.abs(a).max(), 1e-6)
            assert np.abs(a - b).max() / scale < 1e-5, (u, key)


def test_program_cache_bounds_traces():
    """Alternating packed <-> decode ticks reuse the cached compiled
    programs: each engine traces the packed program at most once and
    the decode program at most once for a whole staggered run (the
    jit-lowering counters in runtime.serve pin it)."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=24, token_budget=4)
    before = dict(trace_counts)
    # staggered arrivals force packed ticks (admissions mid-decode)
    # interleaved with decode-only ticks
    eng.submit([1, 2, 3, 4, 5], max_new_tokens=6)
    for _ in range(4):
        eng.step()
    eng.submit([6, 7, 8], max_new_tokens=6)
    eng.run()
    s = eng.stats.summary()
    assert s["packed_ticks"] >= 2 and s["decode_steps"] >= 2
    delta = {k: trace_counts[k] - before.get(k, 0)
             for k in ("packed_step", "serve_step")}
    assert delta["packed_step"] <= 1, delta
    assert delta["serve_step"] <= 1, delta
    # and the program cache holds exactly the two programs, keyed by
    # (kind, token_budget)
    assert set(eng._programs) == {("decode", None), ("packed", 4)}


def test_packed_is_default_and_budget_validated():
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=16)
    assert eng.prefill_mode == "packed"
    assert eng.token_budget == 2 + eng.chunk_len
    with pytest.raises(ValueError):
        ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                      max_cache=16, token_budget=1)
    with pytest.raises(ValueError):
        ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                      max_cache=16, prefill_mode="bogus")


def test_chunk_step_reports_real_vs_padded_tokens():
    """Satellite of the packing work: chunked mode now accounts the
    real-vs-padded split of every launched chunk program, so the
    1-real-row waste the FLOP model exposed is visible in
    EngineStats.summary()."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=16, prefill_mode="chunked",
                        chunk_len=4)
    eng.submit([7, 19, 3, 42, 11], max_new_tokens=2)
    eng.run()
    s = eng.stats.summary()
    # one request, 5 prompt tokens over 2 chunk calls of a 2x4 program
    assert s["chunk_tokens_real"] == 5
    assert s["chunk_tokens_padded"] == 2 * 2 * 4 - 5
    # a tick with nothing mid-prefill never launches the chunk program
    assert eng._chunk_step() == "idle"

"""Subprocess runner: sharded-vs-single-device equivalence on 8 host CPUs.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
         PYTHONPATH=src python tests/shard_equiv_runner.py

Exits non-zero on any mismatch.  Invoked by tests/test_distributed.py so
the main pytest process keeps its single-device view.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.protocol import PrismConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.context import SimulatedContext
from repro.optim import adamw_init
from repro.runtime.train import make_train_step, TrainHParams
from repro.runtime.losses import softmax_xent


def ref_loss(cfg, params, tokens, labels, prism):
    ctx = SimulatedContext(prism, prefix_len=cfg.prefix_len)
    logits, aux = T.forward(cfg, params, tokens, ctx=ctx, chunk=8)
    return softmax_xent(logits, labels)


def check(name, cfg, prism, *, atol=2e-4, compare_grads=True):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    B, N = 8, 32
    tokens = jax.random.randint(key, (B, N), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, N), 0,
                                cfg.vocab_size)

    # ---- single-device reference (simulated P-device protocol) ----
    ref, ref_grads = jax.value_and_grad(
        lambda p: ref_loss(cfg, p, tokens, labels, prism))(params)

    # ---- sharded path ----
    hp = TrainHParams(loss_chunks=4, remat=True, ssm_chunk=8, lr=0.0,
                      grad_clip=1e9)
    step, rules, psh, osh, bsh = make_train_step(cfg, mesh, params, prism, hp)
    params_sh = jax.device_put(params, psh)
    opt = jax.device_put(adamw_init(params), osh)
    batch = jax.device_put({"tokens": tokens, "labels": labels}, bsh)
    new_params, new_opt, metrics = step(params_sh, opt, batch)
    loss_sh = float(metrics["loss"])

    dl = abs(loss_sh - float(ref))
    ok = dl < atol
    print(f"[{name}] loss ref={float(ref):.6f} sharded={loss_sh:.6f} "
          f"diff={dl:.2e} {'OK' if ok else 'FAIL'}")

    if compare_grads and ok:
        # recompute grads via a zero-lr step is awkward; instead re-run the
        # body via a dedicated grads-only step: lr=0 keeps params unchanged,
        # so compare updated optimizer first moment m = (1-b1)*grad.
        got_m = jax.tree.leaves(jax.device_get(new_opt["m"]))
        want = jax.tree.leaves(jax.device_get(ref_grads))
        worst = 0.0
        for gm, wg in zip(got_m, want):
            g = np.asarray(gm) / 0.1          # m = (1-b1)*g with b1=0.9
            w = np.asarray(wg)
            denom = max(1e-6, float(np.abs(w).max()))
            worst = max(worst, float(np.abs(g - w).max()) / denom)
        ok = worst < 5e-3
        print(f"[{name}] grads rel-err={worst:.2e} {'OK' if ok else 'FAIL'}")
    return ok


def main():
    ok = True

    dense = ModelConfig(
        name="tiny-dense", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)
    ok &= check("dense/prism", dense, PrismConfig(P=4, L=2))
    ok &= check("dense/voltage", dense, PrismConfig(P=4, mode="voltage"))

    window = ModelConfig(
        name="tiny-window", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=64,
        blocks=("attn_local", "attn"), window=12, mlp_kind="geglu",
        norm_kind="rmsnorm", pos="rope", qk_norm=True, tie_embeddings=True)
    ok &= check("window/prism", window, PrismConfig(P=4, L=2))

    ssm = ModelConfig(
        name="tiny-xlstm", arch_type="ssm", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
        blocks=("mlstm", "slstm"), norm_kind="rmsnorm", pos="none",
        ssm_heads=2, tie_embeddings=False)
    ok &= check("ssm/xlstm", ssm, PrismConfig(P=4, L=2))

    hybrid = ModelConfig(
        name="tiny-zamba", arch_type="hybrid", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64,
        blocks=("mamba", "shared_attn", "mamba"), norm_kind="rmsnorm",
        pos="rope", ssm_state=8, ssm_heads=4, shared_attn_every=2,
        tie_embeddings=False)
    ok &= check("hybrid/zamba", hybrid, PrismConfig(P=4, L=2))

    moe = ModelConfig(
        name="tiny-moe", arch_type="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=64, vocab_size=64,
        blocks=("moe", "moe"), mlp_kind="swiglu", norm_kind="rmsnorm",
        pos="rope", n_experts=4, top_k=2, expert_d_ff=64,
        capacity_factor=8.0, router_aux_weight=0.0, tie_embeddings=False)
    ok &= check("moe", moe, PrismConfig(P=4, L=2), compare_grads=False)

    print("ALL OK" if ok else "EQUIVALENCE FAILURES")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

import os
import sys

# NOTE: no XLA_FLAGS here — unit tests and benches must see the real
# single CPU device.  Multi-device tests run via subprocess runners
# (test_distributed.py) that set --xla_force_host_platform_device_count
# in the child environment only.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess runners forking an 8-device host mesh")

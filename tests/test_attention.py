"""The identities the reproduction rests on (DESIGN.md §9):

  1. scaling-aware softmax ≡ softmax over row-duplicated K/V (Eq. 12-15)
  2. attention is permutation-invariant in K/V rows (Eq. 5)
  3. CR=1 (segments of size 1) ⇒ PRISM ≡ exact attention
  4. partition-aware mask ≡ global causal mask restricted to the partition
"""
import numpy as np
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core.attention import prism_attention, exact_attention
from repro.core.masks import visibility, exact_cols
from repro.core.protocol import PrismConfig, device_views, partition_bounds
from repro.core.segment_means import duplicate_means, segment_sizes


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(deadline=None, max_examples=25)
@given(b=st.integers(1, 2), nq=st.integers(1, 8), nloc=st.integers(1, 8),
       L=st.integers(1, 4), hq=st.sampled_from([1, 2, 4]),
       grp=st.sampled_from([1, 2]), seed=st.integers(0, 10**6))
def test_scaling_softmax_equals_duplicated(b, nq, nloc, L, hq, grp, seed):
    """Core identity: softmax_g(Q K̂ᵀ) V̂ == softmax(Q Ỹᵀ) Ṽ with Ỹ/Ṽ the
    row-duplicated K/V (exponentiation associativity, Eq. 12)."""
    hkv = max(1, hq // grp)
    hq = hkv * grp
    hd = 4
    n_dup = 3 * L                      # duplicate each mean 3x
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, nq, hq, hd))
    k_loc = jax.random.normal(ks[1], (b, nloc, hkv, hd))
    v_loc = jax.random.normal(ks[2], (b, nloc, hkv, hd))
    kz = jax.random.normal(ks[3], (b, L, hkv, hd))
    vz = jax.random.normal(jax.random.split(ks[3])[0], (b, L, hkv, hd))

    # compressed path: g = [1]*nloc + [3]*L
    k_hat = jnp.concatenate([k_loc, kz], axis=1)
    v_hat = jnp.concatenate([v_loc, vz], axis=1)
    g = jnp.concatenate([jnp.ones(nloc), jnp.full((L,), 3.0)])
    out_c = prism_attention(q, k_hat, v_hat, g=g)

    # duplicated path: repeat each mean row 3x, plain softmax
    k_dup = jnp.concatenate([k_loc, jnp.repeat(kz, 3, axis=1)], axis=1)
    v_dup = jnp.concatenate([v_loc, jnp.repeat(vz, 3, axis=1)], axis=1)
    out_d = exact_attention(q, k_dup, v_dup)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               atol=2e-5)


def test_permutation_invariance():
    """Eq. 5: softmax(Q (Kᵀ P)) (P⁻¹ V) == softmax(Q Kᵀ) V."""
    q, k, v = rand(0, 2, 5, 4, 8), rand(1, 2, 7, 2, 8), rand(2, 2, 7, 2, 8)
    perm = np.random.default_rng(0).permutation(7)
    out = exact_attention(q, k, v)
    out_p = exact_attention(q, k[:, perm], v[:, perm])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                               atol=2e-5)
    # with per-column g, permuting g alongside preserves the result
    g = jnp.asarray([1.0, 2, 3, 1, 4, 1, 2])
    out_g = prism_attention(q, k, v, g=g)
    out_gp = prism_attention(q, k[:, perm], v[:, perm], g=g[perm])
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_gp),
                               atol=2e-5)


def test_cr1_degenerates_to_exact():
    """CR=1 ⇒ L=N_p (segments of size 1) ⇒ means are the tokens
    themselves ⇒ PRISM attention == full causal attention on each
    device's rows (up to the K/V permutation, which Eq. 5 makes free)."""
    b, n, d, h, hd = 1, 12, 16, 2, 8
    x = rand(3, b, n, d)
    wq, wk, wv = rand(10, d, h * hd), rand(11, d, h * hd), rand(12, d, h * hd)

    def proj(t, w):
        return (t @ w).reshape(*t.shape[:-1], h, hd)

    lo, hi = exact_cols(n)
    full_mask = visibility(jnp.arange(n), jnp.asarray(lo), jnp.asarray(hi),
                           causal=True)
    full = exact_attention(proj(x, wq), proj(x, wk), proj(x, wv),
                           mask=full_mask)
    cfg = PrismConfig(P=3, L=4, causal=True)   # N_p = 4 = L -> lossless
    for dv in device_views(x, cfg):
        out = prism_attention(
            proj(dv.x_p, wq), proj(dv.x_hat, wk), proj(dv.x_hat, wv),
            g=jnp.asarray(dv.g, jnp.float32), mask=dv.mask(cfg))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(full[:, dv.row_pos]), atol=2e-5)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(4, 32), p=st.integers(2, 4),
       prefix=st.integers(0, 6))
def test_partition_mask_matches_global(n, p, prefix):
    """Eq. 17: each device's mask over exact columns == the global causal
    mask restricted to the partition's rows."""
    full = np.asarray(visibility(
        jnp.arange(n), *map(jnp.asarray, exact_cols(n)),
        causal=True, prefix_len=prefix))
    for start, size in partition_bounds(n, p):
        rows = jnp.arange(size) + start
        lo, hi = exact_cols(n)
        m = np.asarray(visibility(rows, jnp.asarray(lo), jnp.asarray(hi),
                                  causal=True, prefix_len=prefix))
        np.testing.assert_array_equal(m, full[start:start + size])


def test_mask_means_columns_fig3c():
    """Fig. 3c: means of strictly-preceding partitions fully visible,
    following partitions fully masked, own partition exact triangular."""
    n, p, L = 12, 3, 2
    x = rand(4, 1, n, 8)
    cfg = PrismConfig(P=p, L=L, causal=True)
    views = device_views(x, cfg)
    v1 = views[1]                       # middle device, rows 4..7
    m = np.asarray(v1.mask(cfg))
    n_p = n // p
    # local block lower-triangular
    np.testing.assert_array_equal(m[:, :n_p], np.tril(np.ones((4, 4))) > 0)
    # preceding partition's means (cols n_p..n_p+L-1): visible
    assert m[:, n_p:n_p + L].all()
    # following partition's means: masked
    assert not m[:, n_p + L:].any()


def test_window_mask():
    vis = np.asarray(visibility(
        jnp.arange(8), *map(jnp.asarray, exact_cols(8)),
        causal=True, window=3))
    for i in range(8):
        for j in range(8):
            assert vis[i, j] == (j <= i and j > i - 3)


@settings(deadline=None, max_examples=15)
@given(nq=st.integers(1, 16), m=st.integers(3, 64),
       block=st.sampled_from([4, 8, 16]), causal=st.booleans(),
       seed=st.integers(0, 10**6))
def test_streamed_attention_matches_dense(nq, m, block, causal, seed):
    """§Perf H3: the flash-style streamed path must equal the dense
    scaling softmax for any block size, mask, and g."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (2, nq, 4, 8))
    k = jax.random.normal(ks[1], (2, m, 2, 8))
    v = jax.random.normal(ks[2], (2, m, 2, 8))
    g = jnp.asarray(
        np.random.default_rng(seed).integers(0, 5, size=m), jnp.float32)
    row = jnp.arange(nq) + (m - nq)
    lo, hi = exact_cols(m)
    mask = visibility(row, jnp.asarray(lo), jnp.asarray(hi), causal=causal)
    dense_out = prism_attention(q, k, v, g=g, mask=mask)
    stream_out = prism_attention(q, k, v, g=g, mask=mask, block=block)
    np.testing.assert_allclose(np.asarray(stream_out),
                               np.asarray(dense_out), atol=3e-5, rtol=3e-4)


def test_fully_masked_rows_are_zero_not_nan():
    q, k, v = rand(0, 1, 2, 1, 4), rand(1, 1, 3, 1, 4), rand(2, 1, 3, 1, 4)
    mask = jnp.zeros((2, 3), bool)
    out = prism_attention(q, k, v, mask=mask)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

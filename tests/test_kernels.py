"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracle (ref.py), sweeping shapes / dtypes / GQA groups / mask variants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.protocol import PrismConfig, device_views
from repro.core.segment_means import segment_means
from repro.kernels.ops import prism_attention_op
from repro.kernels.ref import prism_attention_reference
from repro.kernels.segment_means import segment_means_op
from repro.kernels.prism_attention import NEG


def make_case(b, nq, m_loc, L, hq, hkv, hd, *, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = (jax.random.normal(ks[0], (b, nq, hq, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, m_loc + L, hkv, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, m_loc + L, hkv, hd)) * 0.5).astype(dtype)
    # columns: m_loc exact local (positions 0..m_loc-1 == query rows),
    # then L means each covering 4 positions of a remote partition ahead.
    g = np.concatenate([np.ones(m_loc), np.full(L, 4.0)]).astype(np.float32)
    lo = np.concatenate([np.arange(m_loc),
                         m_loc + 4 * np.arange(L)]).astype(np.int32)
    hi = np.concatenate([np.arange(m_loc),
                         m_loc + 4 * np.arange(L) + 3]).astype(np.int32)
    row = np.arange(nq, dtype=np.int32) + (m_loc - nq)
    return q, k, v, jnp.asarray(g), jnp.asarray(lo), jnp.asarray(hi), \
        jnp.asarray(row)


@pytest.mark.parametrize("b,nq,m_loc,L,hq,hkv,hd", [
    (1, 8, 8, 4, 1, 1, 16),
    (2, 16, 16, 8, 4, 2, 32),
    (1, 128, 128, 16, 4, 1, 64),      # block-aligned
    (1, 100, 90, 7, 2, 2, 64),        # ragged -> padding path
    (2, 8, 8, 2, 8, 1, 128),          # MQA, wide heads
    (1, 17, 33, 5, 6, 3, 32),         # odd everything
])
@pytest.mark.parametrize("causal", [True, False])
def test_kernel_vs_ref_shapes(b, nq, m_loc, L, hq, hkv, hd, causal):
    q, k, v, g, lo, hi, row = make_case(b, nq, m_loc, L, hq, hkv, hd)
    got = prism_attention_op(q, k, v, g, lo, hi, row, causal=causal,
                             interpret=True)
    log_g = jnp.where(g > 0, jnp.log(g), NEG)
    want = prism_attention_reference(q, k, v, log_g, lo, hi, row,
                                     causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_kernel_dtypes(dtype, atol):
    q, k, v, g, lo, hi, row = make_case(1, 32, 32, 8, 4, 2, 64, dtype=dtype)
    got = prism_attention_op(q, k, v, g, lo, hi, row, causal=True,
                             interpret=True)
    log_g = jnp.where(g > 0, jnp.log(g), NEG)
    want = prism_attention_reference(q, k, v, log_g, lo, hi, row,
                                     causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-2)


def test_kernel_window_and_prefix():
    q, k, v, g, lo, hi, row = make_case(1, 32, 32, 4, 2, 1, 32)
    for kw in (dict(window=8), dict(prefix_len=6),
               dict(window=16, prefix_len=4)):
        got = prism_attention_op(q, k, v, g, lo, hi, row, causal=True,
                                 interpret=True, **kw)
        log_g = jnp.where(g > 0, jnp.log(g), NEG)
        want = prism_attention_reference(q, k, v, log_g, lo, hi, row,
                                         causal=True, **kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-4)


def test_kernel_g_zero_padding_columns():
    """g=0 columns (own-shard means / ragged pad) get zero weight."""
    q, k, v, g, lo, hi, row = make_case(1, 16, 16, 4, 2, 2, 32)
    g0 = g.at[-2:].set(0.0)
    got = prism_attention_op(q, k, v, g0, lo, hi, row, causal=False,
                             interpret=True)
    want = prism_attention_op(q, k[:, :-2], v[:, :-2], g[:-2], lo[:-2],
                              hi[:-2], row, causal=False, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_kernel_matches_protocol_view():
    """End-to-end: a device_views() view run through the Pallas kernel
    equals the jnp protocol attention (the system-level oracle)."""
    from repro.core.attention import prism_attention
    n, d, h, hd = 24, 16, 2, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, h * hd)) * 0.3
    cfg = PrismConfig(P=3, L=2, causal=True)
    for dv in device_views(x, cfg):
        def proj(t):
            return (t @ w).reshape(*t.shape[:-1], h, hd)
        q, kk, vv = proj(dv.x_p), proj(dv.x_hat), proj(dv.x_hat)
        want = prism_attention(q, kk, vv,
                               g=jnp.asarray(dv.g, jnp.float32),
                               mask=dv.mask(cfg))
        got = prism_attention_op(
            q, kk, vv, jnp.asarray(dv.g, jnp.float32),
            jnp.asarray(dv.col_lo, jnp.int32),
            jnp.asarray(dv.col_hi, jnp.int32),
            jnp.asarray(dv.row_pos, jnp.int32),
            causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------
# segment-means kernel
# ---------------------------------------------------------------------

@pytest.mark.parametrize("b,n,L,d", [(1, 16, 4, 8), (2, 128, 16, 512),
                                     (1, 64, 1, 128), (3, 32, 32, 16)])
def test_segment_means_kernel(b, n, L, d):
    x = jax.random.normal(jax.random.PRNGKey(2), (b, n, d))
    got = segment_means_op(x, L=L, block_d=min(512, d), interpret=True)
    want = segment_means(x, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("b,n,L,d", [(1, 17, 4, 8), (2, 100, 16, 64),
                                     (1, 7, 3, 128), (1, 9, 1, 16)])
def test_segment_means_kernel_ragged(b, n, L, d):
    """N_p % L != 0: the kernel streams the L-1 even segments and
    jnp-reduces the oversized tail — must equal the jnp oracle."""
    x = jax.random.normal(jax.random.PRNGKey(5), (b, n, d))
    got = segment_means_op(x, L=L, block_d=min(512, d), interpret=True)
    want = segment_means(x, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_segment_means_kernel_dtype(dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 64)).astype(dtype)
    got = segment_means_op(x, L=8, block_d=64, interpret=True)
    want = segment_means(x, 8)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)

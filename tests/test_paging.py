"""Paged KV cache tests: PageTable free-list/refcount invariants under
churn, PrefixCache register/lookup/reclaim, the KVCache lifecycle
(plan/reserve/bind/alloc/free + COW fork), and engine-level prefix
reuse — a shared system prompt must cut prefill work without changing
a single token vs the unshared oracle, and the page accounting must
hold after every engine step.  All on the single real CPU device; the
sharded paged-vs-dense equivalence runs via tests/engine_equiv_runner.py.
"""
import numpy as np
import jax
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.paging import (NO_PAGE, KVCache, PagedLayout, PageTable,
                                  PrefixCache, make_paged_layout)
from repro.runtime.serve import (ServeHParams, make_kv_cache, make_layout,
                                 seq_shards)
from repro.serving import EngineConfig, ServingEngine


TINY = ModelConfig(
    name="tiny-paged", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


# --------------------------------------------------------------------------
# PageTable
# --------------------------------------------------------------------------

def test_page_table_churn_invariants():
    """Random alloc/share/free churn holds the refcount == holders
    invariant after every operation; allocation is all-or-nothing."""
    rng = np.random.default_rng(0)
    table = PageTable(16)
    holders: list = []                 # list of page lists we hold refs on
    for _ in range(300):
        op = rng.integers(0, 3)
        if op == 0:                    # alloc 1..4 fresh pages
            got = table.alloc(int(rng.integers(1, 5)))
            if got is not None:
                holders.append(list(got))
        elif op == 1 and holders:      # share an existing holding
            pages = holders[int(rng.integers(len(holders)))]
            table.share(pages)
            holders.append(list(pages))
        elif op == 2 and holders:      # drop one holding
            table.free(holders.pop(int(rng.integers(len(holders)))))
        table.check()
        held = np.zeros(16, np.int64)
        for pages in holders:
            for p in pages:
                held[p] += 1
        assert np.array_equal(held, table.refs.astype(np.int64))
    # over-capacity request: nothing granted, nothing leaked
    free_before = table.free_pages
    assert table.alloc(table.free_pages + 1) is None
    assert table.free_pages == free_before
    for pages in holders:
        table.free(pages)
    assert table.free_pages == 16
    with pytest.raises(ValueError):
        table.free([0])                # double free


# --------------------------------------------------------------------------
# PrefixCache
# --------------------------------------------------------------------------

def test_prefix_cache_register_lookup_reclaim():
    span = 4
    table = PageTable(8)
    prompt = list(range(1, 13))        # 12 tokens = 3 full spans
    pages = table.alloc(3)
    cache = PrefixCache(table)
    # one entry per full-page prefix level
    assert cache.register(prompt, pages, span) == 3
    assert len(cache.entries) == 3
    table.free(pages)                  # owner evicted; entries hold refs
    table.check()
    assert table.free_pages == 5

    # longest strict-prefix hit: a longer prompt reuses all 3 pages ...
    ent = cache.lookup(prompt + [99], span)
    assert ent is not None and ent.tokens == 12 and len(ent.pages) == 3
    # ... but the SAME prompt only reuses 2 (the page holding the final
    # token must stay private for the rewind re-feed)
    ent = cache.lookup(prompt, span)
    assert ent is not None and ent.tokens == 8
    assert cache.lookup([7, 7, 7, 7, 7], span) is None
    assert cache.hits == 2 and cache.misses == 1

    # LRU reclaim refills the free list entry by entry
    dropped = cache.reclaim(6)
    assert dropped >= 1 and table.free_pages >= 6
    table.check()
    cache.clear()
    assert table.free_pages == 8


# --------------------------------------------------------------------------
# KVCache lifecycle (host-side bookkeeping; no device storage needed)
# --------------------------------------------------------------------------

def _host_kv(n_pages=12, ppr=3, n_state=4, prefix=False):
    paging = PagedLayout(page_cols=4, n_seq=1, pages_per_row=ppr,
                         n_pages=n_pages, n_state_pages=n_state)
    kv = KVCache(storage=None, layout=None, paging=paging)
    if prefix:
        kv.prefix = PrefixCache(kv.table)
    return kv


def test_kv_cache_alloc_append_free_lifecycle():
    kv = _host_kv()
    span = kv.paging.span              # 4 tokens
    prompt = list(range(1, 7))         # 6 tokens
    plan = kv.plan(prompt, max_new_tokens=3)     # 9 tokens -> 3 pages
    assert (plan.total_pages, plan.fresh_pages, plan.covered) == (3, 3, 0)
    assert kv.can_admit(plan)
    kv.alloc(0, plan)
    assert len(kv.slot_pages[0]) == 3 and kv.table.free_pages == 9
    kv.append(0, len(prompt) + 3)      # already covered: no-op
    assert len(kv.slot_pages[0]) == 3
    kv.check()

    # full-row plan (paged prism) always takes the whole logical row
    full = kv.plan([1, 2], max_new_tokens=1, full_row=True)
    assert full.total_pages == kv.paging.pages_per_row
    kv.alloc(1, full)
    kv.check()

    # reserve/bind is the two-phase admission the engine gate drives;
    # cancel returns everything
    plan2 = kv.plan([1] * span, max_new_tokens=1)
    assert kv.reserve("r7", plan2)
    kv.check()                         # reserved pages are accounted
    kv.cancel("r7")
    kv.check()
    assert kv.reserve("r8", plan2)
    kv.bind("r8", 2)
    assert len(kv.slot_pages[2]) == plan2.total_pages

    for slot in (0, 1, 2):
        kv.free(slot)
    kv.check()
    assert kv.table.free_pages == kv.paging.n_pages
    assert sorted(kv._state_free) == list(range(4))


def test_kv_cache_out_of_pages_is_all_or_nothing():
    kv = _host_kv(n_pages=4, ppr=4, n_state=2)
    big = kv.plan(list(range(12)), max_new_tokens=4)   # 4 pages
    kv.alloc(0, big)
    assert not kv.can_admit(kv.plan([1, 2], 1), reclaim=False)
    assert not kv.reserve("r1", kv.plan([1, 2], 1))    # nothing committed
    kv.check()
    with pytest.raises(RuntimeError):
        kv.alloc(1, kv.plan([1, 2], 1))
    kv.free(0)
    kv.alloc(1, kv.plan([1, 2], 1))
    kv.check()


def test_kv_cache_prefix_share_and_refcounts():
    kv = _host_kv(prefix=True)
    span = kv.paging.span
    prompt = list(range(1, 2 * span + 2))      # 9 tokens: 2 full spans
    kv.alloc(0, kv.plan(prompt, max_new_tokens=2))
    kv.free(0, prompt=prompt)                  # registers 2 prefix levels
    assert len(kv.prefix.entries) == 2
    kv.check()

    plan = kv.plan(prompt, max_new_tokens=2)   # same prompt again
    assert plan.covered == 2 * span and len(plan.shared) == 2
    assert plan.fresh_pages == plan.total_pages - 2
    kv.alloc(1, plan)
    kv.check()
    # holders per page: page 0 is in BOTH prefix levels + the slot,
    # page 1 in the level-2 entry + the slot
    assert kv.table.refs[plan.shared[0]] == 3
    assert kv.table.refs[plan.shared[1]] == 2
    kv.free(1)
    kv.check()
    kv.prefix.clear()
    assert kv.table.free_pages == kv.paging.n_pages


def test_kv_cache_cow_fork_on_device():
    """ensure_writable forks a shared page to a private copy on the
    device pool: refcounts split, the fork is counted, and the page
    accounting invariant still holds."""
    mesh = _mesh()
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    lay = make_layout(TINY, mesh, 2, 16, hp, 8)
    paging = make_paged_layout(lay, page_tokens=4, n_pages=None, n_slots=2)
    kv = make_kv_cache(TINY, mesh, lay, 2, hp, paging=paging,
                       prefix_cache=True)
    prompt = list(range(1, 6))                 # 5 tokens: 1 full span of 4
    kv.alloc(0, kv.plan(prompt, max_new_tokens=2))
    kv.free(0, prompt=prompt)
    plan = kv.plan(prompt, max_new_tokens=2)
    assert plan.covered == 4
    kv.alloc(1, plan)
    shared_page = kv.slot_pages[1][0]
    assert kv.table.refs[shared_page] == 2

    forked = kv.ensure_writable(1, 0, 3)       # write window inside page 0
    assert forked == 1 and kv.cow_copies == 1
    assert kv.slot_pages[1][0] != shared_page
    assert kv.table.refs[shared_page] == 1     # entry's ref survives
    kv.check()
    # a second call is a no-op: the page is already private
    assert kv.ensure_writable(1, 0, 3) == 0


# --------------------------------------------------------------------------
# engine-level prefix reuse + page accounting
# --------------------------------------------------------------------------

def _engine(params, mesh, **over):
    kw = dict(n_slots=2, prefill_len=16, max_cache=24,
              hp=ServeHParams(decode_mode="exact", ssm_chunk=8),
              chunk_len=4, token_budget=8)
    kw.update(over)
    return ServingEngine(TINY, mesh, params, EngineConfig(**kw))


def test_engine_prefix_hit_matches_unshared_oracle():
    """Two requests sharing a long system prompt: the second maps the
    registered prefix pages COW and skips prefilling the covered
    tokens, yet both outputs are token-identical to an engine with
    prefix reuse disabled."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    shared = rng.integers(1, TINY.vocab_size, size=12).tolist()
    p1, p2 = shared + [5], shared + [7, 9]

    eng = _engine(params, mesh)
    span = eng.kv_cache.paging.span
    assert len(shared) >= span                 # at least one full page
    r1 = eng.submit(p1, max_new_tokens=4)
    out1 = eng.run()[r1]
    assert eng.kv_cache.stats()["prefix_entries"] >= 1
    r2 = eng.submit(p2, max_new_tokens=4)
    out2 = eng.run()[r2]
    s = eng.stats.summary()
    assert s["prefix_hits"] == 1
    assert s["prefix_tokens_saved"] == (len(shared) // span) * span
    eng.kv_cache.check()

    ora = _engine(params, mesh, prefix_cache=False)
    for p, got in ((p1, out1), (p2, out2)):
        rid = ora.submit(p, max_new_tokens=4)
        assert ora.run()[rid] == got
    assert ora.stats.summary()["prefix_hits"] == 0


def test_engine_page_accounting_under_churn():
    """Staggered requests (several sharing a prefix) through a 2-slot
    engine: the full refcount/free-list invariant holds after EVERY
    engine step, and after the drain only prefix entries hold pages."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, mesh)
    rng = np.random.default_rng(11)
    shared = rng.integers(1, TINY.vocab_size, size=10).tolist()
    prompts = [shared + rng.integers(1, TINY.vocab_size,
                                     size=int(rng.integers(1, 4))).tolist()
               if i % 2 == 0 else
               rng.integers(1, TINY.vocab_size,
                            size=int(rng.integers(3, 13))).tolist()
               for i in range(6)]
    for p in prompts:
        eng.submit(p, max_new_tokens=3)
    while eng._sched.has_work:
        eng.step()
        eng.kv_cache.check()
    assert eng.stats.completed == 6
    kv = eng.kv_cache
    assert not kv.slot_pages and not kv._reserved
    held = sum(len(e.pages) for e in kv.prefix.entries.values())
    assert kv.table.free_pages == kv.paging.n_pages - held
    kv.prefix.clear()
    assert kv.table.free_pages == kv.paging.n_pages


def test_engine_out_of_pages_backpressure():
    """A pool sized for one row at a time: the second request blocks at
    the admission gate (counted in EngineStats), admits after the first
    eviction, and both finish.  With prefix reuse on, the registered
    pages of the finished request are LRU-reclaimed to make room."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    pa = rng.integers(1, TINY.vocab_size, size=14).tolist()
    pb = rng.integers(1, TINY.vocab_size, size=14).tolist()

    for prefix_on in (False, True):
        eng = _engine(params, mesh, n_pages=3,
                      prefix_cache=prefix_on)   # 3 pages = one max row
        assert eng.kv_cache.pages_needed(14 + 8) == 3
        ra = eng.submit(pa, max_new_tokens=8)
        rb = eng.submit(pb, max_new_tokens=8)
        out = eng.run()
        s = eng.stats.summary()
        assert set(out) == {ra, rb}
        assert len(out[ra]) == 8 and len(out[rb]) == 8
        assert s["out_of_pages"] >= 1, prefix_on
        eng.kv_cache.check()


def test_engine_config_validation_and_defaults():
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    hp_prism = ServeHParams(decode_mode="prism", ssm_chunk=8, means_cr=2.0)

    # padded admission predates paging and forces the dense rowset
    cfg = EngineConfig(n_slots=2, prefill_len=8, max_cache=16,
                       prefill_mode="padded")
    assert cfg.paged is False and cfg.prefix_cache is False

    # prefix reuse needs the paged exact cache
    cfg = EngineConfig(n_slots=2, prefill_len=8, max_cache=16, hp=hp_prism)
    assert cfg.paged is True and cfg.prefix_cache is False
    with pytest.raises(ValueError):
        EngineConfig(n_slots=2, prefill_len=8, max_cache=16, hp=hp_prism,
                     prefix_cache=True)
    with pytest.raises(ValueError):
        EngineConfig(n_slots=2, prefill_len=8, max_cache=16,
                     prefill_mode="bogus")
    with pytest.raises(ValueError):
        EngineConfig(n_slots=4, prefill_len=8, max_cache=16,
                     token_budget=2)

    # config and legacy kwargs are mutually exclusive
    with pytest.raises(TypeError):
        ServingEngine(TINY, mesh, params,
                      EngineConfig(n_slots=2, prefill_len=8, max_cache=16),
                      n_slots=2)

    # legacy kwargs still construct (the shim builds the EngineConfig)
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=16)
    assert eng.config.paged is True
    assert eng.kv_cache.paged
    # page geometry: spans cover the row exactly
    pg = eng.kv_cache.paging
    assert pg.span * pg.pages_per_row == eng.layout.cap
    n_seq = seq_shards(mesh, 2)
    assert pg.n_seq == n_seq

"""Offload-tier + preemption tests.

Three layers, mirroring the subsystem's split:

  * ``KVStore`` unit behavior (LRU, capacity, drop accounting);
  * churn property tests driving the REAL scheduler + KVCache +
    KVStore host-side bookkeeping through seeded random 300+-op
    sequences of submit/admit/tick/preempt/cancel, asserting the full
    accounting invariants after every op (no slot or page leak,
    refcounts balanced across every spill/restore round-trip, no
    starvation, FIFO within each priority class);
  * device-level engine tests on the 1x1 mesh: preempted-then-restored
    requests are token-identical to the uninterrupted oracle, priority
    pressure preempts automatically, a store that LOSES entries
    triggers clean per-request re-prefill (never a hang or a corrupted
    neighbor), and suspend/resume parks an idle session without
    holding pages.

The sharded (2x4) equivalence — exact AND prism — runs in
``tests/engine_equiv_runner.py`` via its forced-preemption variant.
"""
import numpy as np
import jax
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.offload import KVStore
from repro.runtime.paging import KVCache, PagedLayout, PrefixCache
from repro.runtime.serve import ServeHParams
from repro.serving import (EngineConfig, FifoScheduler, Request,
                           RequestState, SamplingParams, ServingEngine)


TINY = ModelConfig(
    name="tiny-serve", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _req(rid, plen=6, max_new=4, priority=0, arrival=0.0,
         deadline=None):
    return Request(rid=rid, prompt=tuple(range(1, plen + 1)),
                   max_new_tokens=max_new, priority=priority,
                   arrival=arrival, deadline=deadline)


def _host_kv(n_pages=14, ppr=4, n_state=4, prefix=False):
    paging = PagedLayout(page_cols=4, n_seq=1, pages_per_row=ppr,
                         n_pages=n_pages, n_state_pages=n_state)
    kv = KVCache(storage=None, layout=None, paging=paging)
    if prefix:
        kv.prefix = PrefixCache(kv.table)
    return kv


# --------------------------------------------------------------------------
# KVStore
# --------------------------------------------------------------------------

def test_kvstore_put_peek_pop():
    s = KVStore()
    assert s.put("a", 3, None, tokens=9)
    assert "a" in s and len(s) == 1 and s.bytes_used == 3
    ent = s.peek("a")
    assert ent.n_pages == 3 and ent.tokens == 9 and ent.payload is None
    ent = s.pop("a")
    assert ent is not None and "a" not in s and s.bytes_used == 0
    assert s.pop("a") is None
    assert s.hits == 1 and s.misses == 1


def test_kvstore_capacity_evicts_lru():
    s = KVStore(capacity_bytes=5)
    s.put("a", 2, None)
    s.put("b", 2, None)
    s.peek("a")                       # a becomes MRU
    s.put("c", 2, None)               # evicts b (LRU)
    assert "a" in s and "c" in s and "b" not in s
    assert s.evictions == 1 and s.bytes_used == 4
    assert not s.put("big", 9, None)  # larger than capacity: dropped
    assert s.drops == 1 and "big" not in s


def test_kvstore_zero_capacity_drops_everything():
    s = KVStore(capacity_bytes=0)
    assert not s.put("a", 1, None)
    assert len(s) == 0 and s.drops == 1 and s.peek("a") is None


def test_kvstore_replace_same_key():
    s = KVStore()
    s.put("a", 2, None)
    s.put("a", 4, None)
    assert len(s) == 1 and s.bytes_used == 4 and s.peek("a").n_pages == 4


# --------------------------------------------------------------------------
# KVCache spill/restore (host-side refcount handoff)
# --------------------------------------------------------------------------

def test_spill_restore_refcount_roundtrip():
    kv, store = _host_kv(), KVStore()
    plan = kv.plan(range(1, 7), max_new_tokens=3)       # 9 tok -> 3 pages
    assert kv.reserve(7, plan)
    kv.bind(7, slot=0)
    kv.check()
    free0, state0 = kv.table.free_pages, len(kv._state_free)

    n = kv.spill(7, 0, store, tokens=6)
    assert n == 3 and 7 in store
    assert kv.table.free_pages == free0 + 3              # pages handed back
    assert len(kv._state_free) == state0 + 1             # state row too
    assert 0 not in kv.slot_pages and 0 not in kv.slot_state
    kv.check()

    rplan = kv.plan_restore(7, store)
    assert (rplan.total_pages, rplan.fresh_pages, rplan.covered) == (3, 3, 6)
    assert kv.can_admit(rplan) and kv.reserve(7, rplan)
    kv.bind(7, slot=2)                                   # different slot
    assert kv.restore(7, 2, store)
    assert 7 not in store and len(kv.slot_pages[2]) == 3
    kv.check()
    kv.free(2)
    kv.check()
    assert kv.table.free_pages == kv.paging.n_pages


def test_spill_with_shared_prefix_pages_decrefs():
    """A victim holding COW prefix pages spills cleanly: the gathered
    copy is private, the shared pages just decref under their cache
    entries."""
    kv, store = _host_kv(prefix=True), KVStore()
    span = kv.paging.span
    prompt = list(range(1, 10))                          # 9 tok, 2 full pages
    plan = kv.plan(prompt, max_new_tokens=3)
    assert kv.reserve(1, plan)
    kv.bind(1, 0)
    kv.free(0, prompt)                                   # registers prefix
    kv.check()
    plan2 = kv.plan(prompt, max_new_tokens=3)
    assert plan2.covered == 2 * span and len(plan2.shared) == 2
    assert kv.reserve(2, plan2)
    kv.bind(2, 0)
    kv.check()
    kv.spill(2, 0, store, tokens=9)
    kv.check()                                           # refcounts balanced
    rplan = kv.plan_restore(2, store)
    assert rplan.fresh_pages == rplan.total_pages == 3   # all private now
    assert kv.reserve(2, rplan)
    kv.bind(2, 1)
    assert kv.restore(2, 1, store)
    kv.check()
    kv.free(1)
    kv.check()


def test_restore_miss_leaves_pages_bound():
    kv = _host_kv()
    store = KVStore(capacity_bytes=0)                    # loses everything
    plan = kv.plan(range(1, 5), max_new_tokens=4)        # 8 tok -> 2 pages
    assert kv.reserve(3, plan)
    kv.bind(3, 0)
    kv.spill(3, 0, store, tokens=4)                      # dropped on put
    assert 3 not in store
    assert kv.plan_restore(3, store) is None             # caller re-plans
    plan2 = kv.plan(range(1, 5), max_new_tokens=4)
    assert kv.reserve(3, plan2)
    kv.bind(3, 1)
    assert not kv.restore(3, 1, store)                   # miss reported...
    assert len(kv.slot_pages[1]) == 2                    # ...pages intact
    kv.check()


# --------------------------------------------------------------------------
# scheduler policy: priority classes, resume ordering, victim pick
# --------------------------------------------------------------------------

def test_priority_classes_admit_high_first_fifo_within():
    s = FifoScheduler(1)
    for rid, prio in [(0, 0), (1, 1), (2, 0), (3, 1)]:
        s.submit(_req(rid, priority=prio))
    order = []
    while s.queued:
        st = s.admit(now=0.0)[0]
        order.append(st.req.rid)
        s.evict(st, now=1.0)
    assert order == [1, 3, 0, 2]      # class 1 first, FIFO inside each


def test_resume_goes_before_fresh_in_same_class():
    s = FifoScheduler(1)
    s.submit(_req(0))
    (st,) = s.admit(now=0.0)
    st.begin_decode()
    s.preempt(st)                     # park it
    s.submit(_req(1))                 # fresh, same class
    assert s.peek_admit() is st
    (back,) = s.admit(now=2.0)
    assert back is st and back.slot == 0
    assert back.pos == len(back.req.prompt) - 1          # progress kept


def test_fair_resume_ordering_by_arrival():
    s = FifoScheduler(3)
    for rid, arr in [(0, 0.0), (1, 1.0), (2, 2.0)]:
        s.submit(_req(rid, arrival=arr))
    sts = s.admit(now=2.0)
    s.preempt(sts[2])                 # park out of arrival order
    s.preempt(sts[0])
    s.preempt(sts[1])
    order = [st.req.rid for st in s.resume[0]]
    assert order == [0, 1, 2]         # earliest arrival resumes first


def test_pick_victim_lowest_priority_longest_remaining():
    s = FifoScheduler(4)
    specs = [(0, 0, 8), (1, 0, 2), (2, 1, 8), (3, 2, 8)]
    for rid, prio, gen in specs:
        s.submit(_req(rid, plen=4, max_new=gen, priority=prio))
    sts = {st.req.rid: st for st in s.admit(now=0.0)}
    for st in sts.values():
        st.begin_decode()
    # among priorities < 2: class 0 beats class 1; rid0 has more
    # remaining than rid1
    assert s.pick_victim(2) is sts[0]
    sts[0].generated = [5] * 7        # rid0 nearly done: rid1 now longer
    assert s.pick_victim(2) is sts[1]
    assert s.pick_victim(1) in (sts[0], sts[1])
    assert s.pick_victim(0) is None   # nothing strictly below class 0


def test_pick_victim_prefers_decoding_over_prefilling():
    s = FifoScheduler(2)
    s.submit(_req(0, plen=8, max_new=8))
    s.submit(_req(1, plen=4, max_new=2))
    sts = {st.req.rid: st for st in s.admit(now=0.0)}
    sts[1].begin_decode()             # rid1 decoding, rid0 mid-prefill
    assert s.pick_victim(5) is sts[1]


def test_pick_victim_breaks_ties_by_deadline_slack():
    """Deadline/SLO-aware victim policy: within a priority class, the
    victim with the MOST slack (``deadline - now - remaining``) spills
    first; the request racing its deadline is preempted last."""
    s = FifoScheduler(3)
    # same priority, same remaining work — slack alone decides
    s.submit(_req(0, plen=4, max_new=8, deadline=100.0))  # loose SLO
    s.submit(_req(1, plen=4, max_new=8, deadline=12.0))   # tight SLO
    s.submit(_req(2, plen=4, max_new=8))                  # no deadline
    sts = {st.req.rid: st for st in s.admit(now=0.0)}
    for st in sts.values():
        st.begin_decode()
    # no deadline = infinite slack: always the first victim
    assert s.pick_victim(5, now=0.0) is sts[2]
    s.preempt(sts[2])
    # loose SLO spills before tight SLO
    assert s.pick_victim(5, now=0.0) is sts[0]
    s.preempt(sts[0])
    assert s.pick_victim(5, now=0.0) is sts[1]


def test_pick_victim_slack_moves_with_the_clock():
    """Slack is evaluated at ``now``: the same pair of requests swaps
    victim order as one request's deadline closes in."""
    s = FifoScheduler(2)
    s.submit(_req(0, plen=4, max_new=4, deadline=20.0))
    s.submit(_req(1, plen=4, max_new=8, deadline=21.0))
    sts = {st.req.rid: st for st in s.admit(now=0.0)}
    for st in sts.values():
        st.begin_decode()
    # t=0: slack0 = 20-0-4 = 16, slack1 = 21-0-8 = 13 -> rid0 spills
    assert s.pick_victim(9, now=0.0) is sts[0]
    # rid1 finishes most of its work: slack1 = 21-10-1 = 10,
    # slack0 = 20-10-4 = 6 -> victim order flips at t=10
    sts[1].generated = [5] * 7
    assert s.pick_victim(9, now=10.0) is sts[1]


def test_pick_victim_priority_still_dominates_slack():
    """Slack is a TIE-BREAK inside a priority class, never a way for a
    low-priority deadline to outrank a higher class."""
    s = FifoScheduler(2)
    s.submit(_req(0, plen=4, max_new=4, priority=0, deadline=9.0))
    s.submit(_req(1, plen=4, max_new=4, priority=1))      # no deadline
    sts = {st.req.rid: st for st in s.admit(now=0.0)}
    for st in sts.values():
        st.begin_decode()
    # class 0 spills first even though its deadline is tight and the
    # class-1 request has infinite slack
    assert s.pick_victim(5, now=8.0) is sts[0]


def test_scheduler_cancel_queued_and_parked():
    s = FifoScheduler(1)
    s.submit(_req(0))
    s.submit(_req(1))
    (st,) = s.admit(now=0.0)
    s.preempt(st)
    assert s.cancel(1).rid == 1                          # queued fresh
    assert s.cancel(0) is st                             # parked resume
    assert s.cancel(42) is None and s.queued == 0


# --------------------------------------------------------------------------
# churn property test: scheduler + KVCache + KVStore under random ops
# --------------------------------------------------------------------------

class _Churn:
    """Random-op driver over the real host-side subsystem (the engine's
    admission/restore logic mirrored without device storage).  Prompt +
    generation always fit one logical row (<= pages_per_row * span)."""

    def __init__(self, seed, *, n_slots=4, n_pages=14, ppr=4,
                 prefix=False, store=None):
        self.rng = np.random.default_rng(seed)
        self.kv = _host_kv(n_pages=n_pages, ppr=ppr, n_state=n_slots,
                           prefix=prefix)
        self.sched = FifoScheduler(n_slots)
        self.store = KVStore() if store is None else store
        self.flaky = self.store.capacity_bytes is not None
        self.n_slots, self.n_pages = n_slots, n_pages
        self.next_rid = 0
        self.now = 0.0
        self.plans: dict = {}
        self.from_store: set = set()
        self.live: set = set()            # submitted, not finished/cancelled
        self.finished: set = set()
        self.cancelled: set = set()
        self.restarted: set = set()
        self.submit_order: dict = {}      # prio -> [rid]
        self.admit_order: dict = {}       # prio -> [rid first admitted]
        self.admitted_once: set = set()

    # -- engine-mirrored admission gate --------------------------------
    def _fresh_gate(self, req) -> bool:
        kv, pfx = self.kv, self.kv.prefix is not None
        plan = kv.plan(req.prompt, req.max_new_tokens, use_prefix=pfx)
        if not kv.can_admit(plan, reclaim=False):
            if kv.prefix is not None:
                kv.prefix.reclaim(plan.fresh_pages)
                plan = kv.plan(req.prompt, req.max_new_tokens,
                               use_prefix=pfx)
            if not kv.can_admit(plan, reclaim=False):
                return False
        if not kv.reserve(req.rid, plan):
            return False
        self.plans[req.rid] = plan
        return True

    def _gate(self, cand) -> bool:
        if not isinstance(cand, RequestState):
            return self._fresh_gate(cand)
        rid = cand.req.rid
        plan = self.kv.plan_restore(rid, self.store)
        if plan is None:                  # store lost it: re-prefill
            cand.reset_for_refill()
            self.restarted.add(rid)
            return self._fresh_gate(cand.req)
        if not (self.kv.can_admit(plan) and self.kv.reserve(rid, plan)):
            return False
        self.plans[rid] = plan
        self.from_store.add(rid)
        return True

    # -- ops -----------------------------------------------------------
    def op_submit(self):
        rid = self.next_rid
        self.next_rid += 1
        plen = int(self.rng.integers(1, 9))
        gen = int(self.rng.integers(1, 9))
        prio = int(self.rng.integers(0, 3))
        prompt = tuple(int(t) for t in self.rng.integers(1, 50, size=plen))
        self.sched.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=gen, priority=prio,
                                  arrival=self.now))
        self.live.add(rid)
        self.submit_order.setdefault(prio, []).append(rid)
        self.now += 1.0

    def op_admit(self):
        for st in self.sched.admit(self.now, gate=self._gate):
            rid = st.req.rid
            self.kv.bind(rid, st.slot)
            plan = self.plans.pop(rid)
            if rid in self.from_store:
                self.from_store.discard(rid)
                if not self.kv.restore(rid, st.slot, self.store):
                    st.reset_for_refill()
                    self.restarted.add(rid)
            elif plan.covered and st.nprefilled < plan.covered:
                st.nprefilled = plan.covered
            if rid not in self.admitted_once:
                self.admitted_once.add(rid)
                self.admit_order.setdefault(st.req.priority, []).append(rid)

    def op_tick(self):
        for st in self.sched.prefilling():
            st.nprefilled += min(3, len(st.req.prompt) - st.nprefilled)
            if not st.prefilling:
                st.begin_decode()
        for st in self.sched.decoding():
            st.generated.append(7)
            st.pos += 1
            st.next_token = 7
            if st.finished():
                self.kv.free(st.slot, st.req.prompt
                             if self.kv.prefix is not None else None)
                self.sched.evict(st, self.now)
                self.finished.add(st.req.rid)
                self.live.discard(st.req.rid)
        self.now += 1.0

    def op_preempt(self):
        active = list(self.sched.active.values())
        if not active:
            return
        st = active[int(self.rng.integers(len(active)))]
        self.kv.spill(st.req.rid, st.slot, self.store,
                      tokens=st.nprefilled)
        self.sched.preempt(st)

    def op_cancel(self):
        waiting = ([r for q in self.sched.queues.values() for r in q]
                   + [s.req for q in self.sched.resume.values() for s in q])
        if not waiting:
            return
        rid = waiting[int(self.rng.integers(len(waiting)))].rid
        assert self.sched.cancel(rid) is not None
        self.store.drop(rid)
        self.cancelled.add(rid)
        self.live.discard(rid)

    # -- invariants ----------------------------------------------------
    def check(self):
        self.kv.check()                   # refcounts == holders, state rows
        sch = self.sched
        assert sorted(sch.free_slots + list(sch.active)) \
            == list(range(self.n_slots)), "slot leak"
        for slot, st in sch.active.items():
            assert st.slot == slot and slot in self.kv.slot_pages
        assert self.store.bytes_used \
            == sum(e.nbytes for e in self.store._entries.values())
        if not self.flaky:
            for q in sch.resume.values():
                for st in q:
                    assert st.req.rid in self.store, \
                        f"parked rid {st.req.rid} lost its store entry"
        for q in sch.resume.values():
            arrivals = [st.req.arrival for st in q]
            assert arrivals == sorted(arrivals), "unfair resume order"

    def drain(self):
        for _ in range(3000):
            if not self.sched.has_work:
                return
            self.op_admit()
            self.op_tick()
            self.check()
        raise AssertionError("churn did not drain: starvation or leak")

    def run(self, n_ops=320):
        ops = [self.op_submit, self.op_admit, self.op_tick,
               self.op_preempt, self.op_cancel]
        weights = [0.28, 0.22, 0.30, 0.13, 0.07]
        for _ in range(n_ops):
            self.rng.choice(ops, p=weights)()
            self.check()
        self.drain()
        # zero page/slot/state leak after everything completes (prefix
        # entries hold pages by design — drop them before the audit)
        if self.kv.prefix is not None:
            self.kv.prefix.clear()
        assert self.kv.table.free_pages == self.n_pages
        assert sorted(self.kv._state_free) == list(range(self.n_slots))
        assert self.sched.free_slots == list(range(self.n_slots))
        if not self.flaky:
            assert len(self.store) == 0, "orphaned store entries"
        # no starvation: every non-cancelled request finished
        assert self.finished == set(range(self.next_rid)) - self.cancelled
        # FIFO preserved within each priority class (first admissions)
        for prio, order in self.admit_order.items():
            expect = [r for r in self.submit_order[prio] if r in order]
            assert order == expect, f"class {prio} lost FIFO order"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_churn_300_ops_no_leaks(seed):
    _Churn(seed).run(n_ops=320)


def test_churn_with_prefix_cache():
    _Churn(5, prefix=True).run(n_ops=320)


def test_churn_with_flaky_store_recovers():
    """A capacity-starved store drops/evicts spilled entries; every
    affected request must restart cleanly and still finish."""
    c = _Churn(7, store=KVStore(capacity_bytes=5))
    c.run(n_ops=320)
    assert c.store.drops + c.store.evictions > 0
    assert c.restarted, "flaky store never exercised the restart path"


# --------------------------------------------------------------------------
# engine end-to-end (1x1 mesh, real device storage)
# --------------------------------------------------------------------------

def _engine(params, mesh, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("max_cache", 24)
    # prefix off: these tests pin EXACT page accounting after drain
    # (prefix entries intentionally outlive their owners); the
    # prefix+preemption interaction is covered by the host-side spill
    # tests above and the equiv runner's forced-preemption cells
    kw.setdefault("prefix_cache", False)
    return ServingEngine(TINY, mesh, params, **kw)


def _submit_mix(eng, n=4, gen=6):
    rng = np.random.default_rng(3)
    for i in range(n):
        plen = int(rng.integers(3, 9))
        prompt = rng.integers(1, TINY.vocab_size, size=plen)
        eng.submit(prompt, max_new_tokens=gen,
                   sampling=SamplingParams(seed=i))


def test_engine_preempt_restore_token_identical():
    """Every request force-preempted mid-decode and restored must
    reproduce the uninterrupted oracle token-for-token (the 2x4 /
    prism version runs in engine_equiv_runner.py)."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True)
    _submit_mix(eng)
    hit = set()
    for _ in range(400):
        if not eng._sched.has_work and not eng._pending:
            break
        eng.step()
        for st in list(eng._sched.active.values()):
            rid = st.req.rid
            if (rid not in hit and not st.prefilling
                    and len(st.generated) >= 1 and not st.finished()):
                assert eng.preempt(rid)
                hit.add(rid)
        eng.kv_cache.check()
    assert eng.results() == want
    assert len(hit) == 4
    assert eng.stats.preemptions >= 4 and eng.stats.restore_hits >= 4
    assert eng.stats.spilled_pages > 0 and eng.stats.restore_misses == 0
    assert len(eng.kv_store) == 0
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages


def test_engine_priority_pressure_preempts_and_recovers():
    """With the pool page-starved, a higher-priority arrival preempts
    the lowest-priority longest-remaining decode; after the load
    drains, backpressure is gone and the pool is fully free."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True, n_pages=6)
    rng = np.random.default_rng(0)
    for i in range(3):                     # 2 pages each: pool now full
        eng.submit(rng.integers(1, 61, size=8), max_new_tokens=8,
                   priority=0)
    for _ in range(30):                    # all three decoding
        eng.step()
        if len(eng._sched.decoding()) == 3:
            break
    hi = eng.submit(rng.integers(1, 61, size=6), max_new_tokens=4,
                    priority=1)
    eng.step()                             # blocked -> preempt -> admit
    assert eng.stats.preemptions >= 1
    assert any(st.req.rid == hi for st in eng._sched.active.values())
    out = eng.run()
    assert sorted(out) == [0, 1, 2, hi]
    assert all(len(v) > 0 for v in out.values())
    # backpressure fully cleared: pool free, store empty, and a fresh
    # request admits without a single new out_of_pages event
    kv = eng.kv_cache
    assert kv.table.free_pages == kv.paging.n_pages
    assert len(eng.kv_store) == 0
    blocked = eng.stats.out_of_pages
    eng.submit(rng.integers(1, 61, size=4), max_new_tokens=2)
    eng.run()
    assert eng.stats.out_of_pages == blocked


def test_engine_lost_restore_reprefills_cleanly():
    """A store that drops every spill (total host-memory pressure) must
    surface per-request recovery — re-prefill, same final tokens under
    greedy sampling — and never corrupt a neighbor request."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True)
    eng._store = KVStore(capacity_bytes=0)     # fault injection
    _submit_mix(eng, n=2)
    preempted = False
    for _ in range(400):
        if not eng._sched.has_work and not eng._pending:
            break
        eng.step()
        if not preempted:
            for st in list(eng._sched.active.values()):
                if st.req.rid == 0 and len(st.generated) >= 2:
                    assert eng.preempt(0)
                    preempted = True
        eng.kv_cache.check()
    assert preempted
    assert eng.results() == want               # rid 0 reran, rid 1 untouched
    assert eng.stats.restore_misses >= 1 and eng.stats.restore_hits == 0
    assert eng._results[0].restarts >= 1
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages


def test_engine_suspend_resume_idle_session():
    params = T.init(TINY, jax.random.PRNGKey(0))
    mesh = _mesh()
    oracle = _engine(params, mesh)
    _submit_mix(oracle, n=2)
    want = oracle.run()

    eng = _engine(params, mesh, offload=True)
    _submit_mix(eng, n=2)
    for _ in range(200):
        st = eng._find_active(0)
        if st is not None and len(st.generated) >= 2:
            break
        eng.step()
    assert eng.suspend(0)
    assert eng._find_active(0) is None and 0 in eng.kv_store
    out = eng.run()                            # finishes rid 1 only
    assert 0 not in out and out[1] == want[1]
    assert eng.resume(0)
    out = eng.run()
    assert out[0] == want[0]                   # warm restore, same tokens
    assert len(eng.kv_store) == 0


def test_engine_cancel_waiting_request():
    """cancel() reaches every lifecycle state — including ACTIVE
    mid-flight (PR-9: the streaming front-end cancels decoding
    requests through this path).  The queued victim vanishes, the
    active victim releases its slot/pages zero-leak and lands in
    failed(), and the survivor finishes untouched."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True, n_slots=2)
    _submit_mix(eng, n=2)
    queued = eng.submit((1, 2, 3), max_new_tokens=2)    # no free slot yet
    eng.step()
    assert eng.cancel(queued)
    assert eng.cancel(0)                       # active: cancellable
    assert eng.failed()[0] == "cancelled"
    out = eng.run()
    assert queued not in out and sorted(out) == [1]
    assert eng.stats.cancelled == 2
    eng.kv_cache.check()
    assert (eng.kv_cache.table.free_pages
            == eng.kv_cache.paging.n_pages)


def test_engine_double_cancel_idempotent():
    """cancel() is idempotent: the second call (and a cancel of an
    unknown rid) returns False and leaves the bookkeeping intact."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True, n_slots=2)
    _submit_mix(eng, n=2)
    queued = eng.submit((1, 2, 3), max_new_tokens=2)
    eng.step()
    assert eng.cancel(queued)
    assert not eng.cancel(queued)              # double-cancel: no-op
    assert not eng.cancel(999)                 # never submitted
    eng.kv_cache.check()
    out = eng.run()
    assert sorted(out) == [0, 1]
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages


def test_engine_cancel_while_spilled_then_recancel():
    """Cancelling a spilled (resume-parked) request reclaims its store
    entry; the second cancel returns False and nothing leaks."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True)
    _submit_mix(eng, n=2)
    for _ in range(200):
        st = eng._find_active(0)
        if st is not None and len(st.generated) >= 1:
            break
        eng.step()
    assert eng.preempt(0)
    assert 0 in eng.kv_store
    assert eng.cancel(0)                       # parked on the resume queue
    assert 0 not in eng.kv_store               # store bytes reclaimed
    assert not eng.cancel(0)                   # double-cancel: no-op
    out = eng.run()
    assert sorted(out) == [1]
    eng.kv_cache.check()
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages
    assert eng.kv_store.bytes_used == 0


def test_engine_resume_after_cancel_returns_false():
    """resume() of a cancelled (formerly suspended) session returns
    False — the cancel won; no store entry, no ghost requeue."""
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True)
    _submit_mix(eng, n=2)
    for _ in range(200):
        st = eng._find_active(0)
        if st is not None and len(st.generated) >= 1:
            break
        eng.step()
    assert eng.suspend(0)
    assert eng.cancel(0)                       # cancels the parked session
    assert not eng.resume(0)                   # resume-after-cancel: no-op
    assert not eng.suspend(0)                  # not active either
    out = eng.run()
    assert sorted(out) == [1]
    assert len(eng.kv_store) == 0 and eng.kv_store.bytes_used == 0
    eng.kv_cache.check()


def test_engine_double_suspend_double_resume():
    params = T.init(TINY, jax.random.PRNGKey(0))
    eng = _engine(params, _mesh(), offload=True)
    _submit_mix(eng, n=2)
    for _ in range(200):
        st = eng._find_active(0)
        if st is not None and len(st.generated) >= 1:
            break
        eng.step()
    assert eng.suspend(0)
    assert not eng.suspend(0)                  # already parked
    assert eng.resume(0)
    assert not eng.resume(0)                   # already requeued
    out = eng.run()
    assert sorted(out) == [0, 1]
    assert len(eng.kv_store) == 0
    eng.kv_cache.check()
    assert eng.kv_cache.table.free_pages == eng.kv_cache.paging.n_pages


def test_engine_config_offload_validation():
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(n_slots=2, prefill_len=8, max_cache=16,
                     offload=True, paged=False)
    with pytest.raises(ValueError, match="gang"):
        EngineConfig(n_slots=2, prefill_len=8, max_cache=16,
                     offload=True, gang=True)
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(n_slots=2, prefill_len=8, max_cache=16,
                     offload=True, prefill_mode="padded")

"""Chunked-prefill tests on the single real CPU device (mesh 1x1; the
sharded versions run via tests/engine_equiv_runner.py):

* the chunk program writes the SAME cache the monolithic prefill
  writes (exact mode, mixed per-row offsets, ragged final chunks);
* prism Segment-Means state is captured over REAL columns only — the
  regression test for the old padded-prefill wart where a short
  prompt's kz/vz averaged pad columns;
* engine-level: prompt lengths exactly at / off chunk boundaries match
  a teacher-forced ``T.forward`` oracle, and the legacy padded mode
  still serves correctly.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.protocol import PrismConfig
from repro.core.segment_means import segment_fill_counts
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.serve import (ServeHParams, grow_cache, init_cache,
                                 make_chunk_prefill_step,
                                 make_prefill_step, make_serve_step)
from repro.serving import ServingEngine


TINY = ModelConfig(
    name="tiny-serve", arch_type="dense", n_layers=2, d_model=32,
    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=61,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    tie_embeddings=True)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _chunk_fill(chunk, params, cache, prompts, chunk_len, n_slots):
    """Drive the chunk program like the engine does: every mid-prefill
    row advances each call, rows at different offsets."""
    prog = {s: 0 for s in prompts}               # slot -> offset
    while any(prog[s] < len(p) for s, p in prompts.items()):
        toks = np.zeros((n_slots, chunk_len), np.int32)
        off = np.full(n_slots, -1, np.int32)
        nreal = np.zeros(n_slots, np.int32)
        for s, p in prompts.items():
            if prog[s] >= len(p):
                continue
            take = min(chunk_len, len(p) - prog[s])
            toks[s, :take] = p[prog[s]:prog[s] + take]
            off[s] = prog[s]
            nreal[s] = take
            prog[s] += take
        cache = chunk(params, cache, jnp.asarray(toks), jnp.asarray(off),
                      jnp.asarray(nreal))
    return cache


def test_chunked_cache_matches_monolithic_exact():
    """Chunked prefill (ragged chunks, rows at different offsets) lays
    down bit-comparable K/V to the monolithic prefill, and the decode
    logits from both caches agree."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    n0, cap, C, B = 8, 16, 3, 4                   # 3 does not divide 8
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    prism = PrismConfig(P=1, mode="voltage")
    rng = np.random.default_rng(0)
    p1 = rng.integers(1, TINY.vocab_size, size=n0)
    p3 = rng.integers(1, TINY.vocab_size, size=5)

    pre, lp, _, _ = make_prefill_step(TINY, mesh, params, prism,
                                      batch=B, n=n0, hp=hp)
    batch = np.zeros((B, n0), np.int32)
    batch[1], batch[3, :5] = p1, p3
    _, ref = pre(params, {"tokens": jnp.asarray(batch)})
    step, ld, _, _ = make_serve_step(TINY, mesh, params, batch=B,
                                     cap=cap, prefill_len=n0, hp=hp)
    ref = grow_cache(ref, lp, ld)

    chunk, lc, _ = make_chunk_prefill_step(
        TINY, mesh, params, batch=B, cap=cap, prefill_len=n0,
        chunk_len=C, hp=hp)
    assert lc == ld
    got = _chunk_fill(chunk, params, init_cache(TINY, ld, B, hp),
                      {1: p1, 3: p3}, C, B)

    for u in range(2):
        for key in ("k", "v"):
            a = np.asarray(ref["scan"][0][key][u])   # (B, cap, H, hd)
            b = np.asarray(got["scan"][0][key][u])
            # row 1: all n0 positions real; row 3: first 5 real
            assert np.abs(a[1, :n0] - b[1, :n0]).max() < 1e-5, (u, key)
            assert np.abs(a[3, :5] - b[3, :5]).max() < 1e-5, (u, key)

    # decode from both caches: teacher-forced logits agree
    tok = np.array([0, p1[-1], 0, 0], np.int32)
    pos = np.array([-1, n0 - 1, -1, -1], np.int32)
    la, ref = step(params, ref, jnp.asarray(tok), jnp.asarray(pos))
    lb, got = step(params, got, jnp.asarray(tok), jnp.asarray(pos))
    a, b = np.asarray(la[1]), np.asarray(lb[1])
    assert np.abs(a - b).max() / np.abs(a).max() < 1e-5


def test_prism_means_capture_real_columns_only():
    """THE regression test for the padded-prefill wart: a short
    prompt's Segment-Means state must match the UNPADDED reference —
    counts are real-token counts, sums/values average no pad column.
    (The monolithic voltage prefill at n = len(prompt) computes the
    same quantities over a prompt that needs no padding; vz and zsum
    carry no positional encoding, so they must agree exactly.)"""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    n0, cap, plen = 8, 16, 6
    hp = ServeHParams(decode_mode="prism", ssm_chunk=8, means_cr=8.0)
    prompt = np.asarray([7, 19, 3, 42, 11, 23], np.int32)
    assert plen == len(prompt)

    # engine-style chunked prefill into an n0 = 8 slot (L = 1 segment)
    chunk, lay, _ = make_chunk_prefill_step(
        TINY, mesh, params, batch=2, cap=cap, prefill_len=n0,
        chunk_len=4, hp=hp)
    assert lay.L == 1
    cache = _chunk_fill(chunk, params, init_cache(TINY, lay, 2, hp),
                        {0: prompt}, 4, 2)

    # unpadded reference: monolithic voltage prefill over exactly plen
    # tokens — same single segment over only-real columns
    prism = PrismConfig(P=1, cr=8.0, mode="voltage")
    pre, lpp, _, _ = make_prefill_step(TINY, mesh, params, prism,
                                       batch=1, n=plen, hp=hp)
    _, ref = pre(params, {"tokens": jnp.asarray(prompt[None])})

    for u in range(2):
        gz = np.asarray(cache["scan"][0]["gz"][u, 0])
        assert gz.tolist() == [float(plen)], gz   # real count, NOT n0
        for key in ("vz", "zsum"):
            a = np.asarray(ref["scan"][0][key][u, 0])
            b = np.asarray(cache["scan"][0][key][u, 0])
            scale = max(np.abs(a).max(), 1e-6)
            assert np.abs(a - b).max() / scale < 1e-5, (u, key)

    # the counts the engine wrote == the analytic fill counts
    from repro.runtime.serve import _means_meta
    lo, hi, _, _, _ = _means_meta(lay)
    want = segment_fill_counts(lo, hi, plen)
    assert np.allclose(np.asarray(cache["scan"][0]["gz"][0, 0]),
                       np.asarray(want))


def test_padded_mode_prism_gz_shows_the_wart():
    """The legacy padded flush captures means over the whole padded
    region: gz reports the full segment size even though the prompt is
    shorter — exactly what the chunked path fixes."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode="prism", ssm_chunk=8, means_cr=8.0)
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=16, hp=hp, prefill_mode="padded")
    eng.submit([7, 19, 3, 42, 11, 23], max_new_tokens=1)
    eng.run()
    gz = np.asarray(eng.kv_cache.storage["scan"][0]["gz"][0, 0])
    assert gz.tolist() == [8.0], gz               # pads counted: the wart

    # paged=False: the gz-by-slot-row read below is dense-layout
    # addressing (the paged prism engine pools this state per request)
    eng2 = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                         max_cache=16, hp=hp, chunk_len=4, paged=False)
    eng2.submit([7, 19, 3, 42, 11, 23], max_new_tokens=1)
    eng2.run()
    gz2 = np.asarray(eng2.kv_cache.storage["scan"][0]["gz"][0, 0])
    assert gz2.tolist() == [6.0], gz2             # real columns only


def test_engine_chunk_boundary_prompt_lengths():
    """Prompt lengths exactly at, one below, and one above a chunk
    boundary all match the teacher-forced oracle (chunk_len = 4)."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    for plen in (4, 8, 3, 5):
        prompt = rng.integers(1, TINY.vocab_size, size=plen).tolist()
        eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                            max_cache=24, chunk_len=4,
                            prefill_mode="chunked")
        rid = eng.submit(prompt, max_new_tokens=4)
        got = eng.run()[rid]
        seq = list(prompt)
        for _ in range(4):
            logits, _ = T.forward(TINY, params, jnp.asarray([seq]),
                                  chunk=8)
            seq.append(int(np.argmax(np.asarray(logits[0, -1]))))
        assert got == seq[plen:], (plen, got, seq[plen:])
        want_chunks = -(-plen // 4)
        assert eng.stats.prefill_chunks == want_chunks
        assert eng.stats.prefill_tokens == plen


def test_engine_padded_mode_still_serves():
    """Legacy admission path: the padded flush + rewind still matches
    the teacher-forced oracle."""
    mesh = _mesh()
    params = T.init(TINY, jax.random.PRNGKey(0))
    prompt = [7, 19, 3, 42, 11]
    eng = ServingEngine(TINY, mesh, params, n_slots=2, prefill_len=8,
                        max_cache=16, prefill_mode="padded")
    rid = eng.submit(prompt, max_new_tokens=3)
    got = eng.run()[rid]
    seq = list(prompt)
    for _ in range(3):
        logits, _ = T.forward(TINY, params, jnp.asarray([seq]), chunk=8)
        seq.append(int(np.argmax(np.asarray(logits[0, -1]))))
    assert got == seq[len(prompt):]
    assert eng.stats.prefill_chunks == 0 and eng.stats.prefills == 1

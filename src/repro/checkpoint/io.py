"""Flat-key .npz checkpointing for arbitrary pytrees (params + opt state).

Keys are '/'-joined tree paths; restore rebuilds into a provided
template tree (so dtypes/shardings are re-applied by the caller)."""
from __future__ import annotations

import os
import re

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(dirpath: str, step: int, tree) -> str:
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(dirpath: str) -> int | None:
    if not os.path.isdir(dirpath):
        return None
    steps = [int(m.group(1)) for f in os.listdir(dirpath)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(dirpath: str, step: int, template):
    path = os.path.join(dirpath, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    flat_paths = ["/".join(
        str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    leaves = [data[k].astype(np.asarray(t).dtype)
              for k, t in zip(flat_paths, leaves_t)]
    return treedef.unflatten(leaves)

"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

Optimizer state mirrors the parameter tree (so the same sharding rules
apply to ``m``/``v`` as to the parameters themselves — see
``repro.sharding.rules``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, state, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    c1 = 1 - b1 ** sf
    c2 = 1 - b2 ** sf

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh = m / c1
        vh = v / c2
        # decoupled weight decay; no decay on 1-D params (norms, biases)
        decay = weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}

from repro.models.config import ModelConfig

# Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]
# moe: 35L d_model=7168 56H (GQA kv=8), 128 experts top-2 (expert
# d_ff=4864) + parallel dense-residual FFN, vocab=32000.
CONFIG = ModelConfig(
    name="arctic-480b", arch_type="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000, blocks=("moe",) * 35,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
    n_experts=128, top_k=2, expert_d_ff=4864, moe_dense_d_ff=4864,
    tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base",
)

from repro.models.config import ModelConfig

# xLSTM 1.3B [arXiv:2405.04517]
# ssm: 48L d_model=2048, 4 heads, mLSTM:sLSTM 7:1 (every 8th layer sLSTM),
# no FFN (cells carry their own expansion), vocab=50304.
# PRISM applicability (DESIGN.md §6): mLSTM uses constant-size state
# handoff across sequence partitions; sLSTM is sequential (inapplicable).
_blocks = tuple("slstm" if i % 8 == 7 else "mlstm" for i in range(48))
CONFIG = ModelConfig(
    name="xlstm-1.3b", arch_type="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, blocks=_blocks,
    norm_kind="rmsnorm", pos="none", ssm_heads=4, ssm_expand=2,
    tie_embeddings=False,
    source="arXiv:2405.04517",
)

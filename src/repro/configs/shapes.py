"""The four assigned input shapes and per-(arch, shape) applicability."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def applicable(arch: str, shape: str) -> bool:
    """All 10 assigned archs run all 4 shapes (DESIGN.md §6): decode shapes
    lower `serve_step`; long_500k is sub-quadratic for SSM/hybrid natively
    and via PRISM-compressed (or sliding-window) attention for the rest —
    PRISM itself is the sub-quadratic variant the assignment asks for."""
    return True

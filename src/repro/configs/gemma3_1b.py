from repro.models.config import ModelConfig

# Gemma 3 1B [hf:google/gemma-3-1b-pt]
# dense: 26L d_model=1152 4H (MQA kv=1) d_ff=6912 vocab=262144,
# 5:1 local(sliding-window 512):global attention, head_dim=256,
# dual rope theta (local 10k / global 1M), qk-norm, 128k-class context.
_blocks = tuple("attn" if (i + 1) % 6 == 0 else "attn_local"
                for i in range(26))
CONFIG = ModelConfig(
    name="gemma3-1b", arch_type="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144, blocks=_blocks,
    mlp_kind="geglu", norm_kind="rmsnorm", pos="rope",
    rope_theta=1e6, rope_theta_local=10000.0, qk_norm=True,
    embed_scale=True, window=512, tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)

from repro.models.config import ModelConfig

# ViT-B/16 [arXiv:2010.11929] — the paper's vision model (Tables II, IV).
# 12L d=768 12H d_ff=3072, 196 patches + CLS = 197 tokens, encoder.
# Patch-embedding conv is provided as flattened-patch dense (stub-style).
CONFIG = ModelConfig(
    name="vit-b16", arch_type="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=0, num_classes=1000,
    mlp_kind="gelu", norm_kind="layernorm", pos="learned", causal=False,
    attn_bias=True, max_seq=224, frontend="patch_stub",
    source="arXiv:2010.11929",
)

from repro.models.config import ModelConfig

# Yi 6B [arXiv:2403.04652]
# dense llama-arch: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64000,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope", rope_theta=5e6,
    tie_embeddings=False,
    source="arXiv:2403.04652",
)

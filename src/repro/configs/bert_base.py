from repro.models.config import ModelConfig

# BERT-base [NAACL 2019] — the paper's NLU model (Table V).
CONFIG = ModelConfig(
    name="bert-base", arch_type="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=30522, num_classes=2,
    mlp_kind="gelu", norm_kind="layernorm", pos="learned", causal=False,
    attn_bias=True, max_seq=512,
    source="NAACL 2019 (Devlin et al.)",
)

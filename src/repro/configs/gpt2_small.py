from repro.models.config import ModelConfig

# GPT-2 small [Radford et al. 2019] — the paper's AR model (Table VI).
CONFIG = ModelConfig(
    name="gpt2-small", arch_type="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=50257,
    mlp_kind="gelu", norm_kind="layernorm", pos="learned", causal=True,
    attn_bias=True, max_seq=1024, tie_embeddings=True,
    source="GPT-2 (Radford et al., 2019)",
)

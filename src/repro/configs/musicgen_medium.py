from repro.models.config import ModelConfig

# MusicGen-medium decoder [arXiv:2306.05284]
# audio: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 (EnCodec codes).
# Frontend (EnCodec conv codec) is a stub per the assignment carve-out:
# input_specs() provides precomputed frame embeddings.
CONFIG = ModelConfig(
    name="musicgen-medium", arch_type="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    mlp_kind="gelu", norm_kind="layernorm", pos="sincos",
    attn_bias=False, tie_embeddings=False, frontend="encodec_stub",
    source="arXiv:2306.05284",
)

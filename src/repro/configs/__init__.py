"""Architecture registry: ``get_config("<arch-id>")`` for every assigned
architecture (plus the paper's own three models)."""
from __future__ import annotations

import importlib

# arch-id -> module name
_REGISTRY = {
    "command-r-35b": "command_r_35b",
    "musicgen-medium": "musicgen_medium",
    "gemma-7b": "gemma_7b",
    "paligemma-3b": "paligemma_3b",
    "xlstm-1.3b": "xlstm_1_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "yi-6b": "yi_6b",
    "zamba2-2.7b": "zamba2_2_7b",
    "gemma3-1b": "gemma3_1b",
    "arctic-480b": "arctic_480b",
    # paper models
    "vit-b16": "vit_b16",
    "bert-base": "bert_base",
    "gpt2-small": "gpt2_small",
}

ASSIGNED_ARCHS = tuple(list(_REGISTRY)[:10])
PAPER_ARCHS = ("vit-b16", "bert-base", "gpt2-small")
ALL_ARCHS = tuple(_REGISTRY)


def get_config(arch: str):
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG

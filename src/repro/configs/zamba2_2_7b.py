from repro.models.config import ModelConfig

# Zamba2 2.7B [arXiv:2411.15242]
# hybrid: 54 Mamba2 layers (ssm_state=64) with a SHARED attention+MLP
# block interleaved every 6th layer (weight sharing), d_model=2560,
# 32H (kv=32), shared-MLP d_ff=10240, vocab=32000.
_blocks = tuple("shared_attn" if i % 6 == 5 else "mamba" for i in range(54))
CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, blocks=_blocks,
    mlp_kind="gelu", norm_kind="rmsnorm", pos="rope",
    ssm_state=64, ssm_heads=32, ssm_expand=2, ssm_conv=4,
    shared_attn_every=6, tie_embeddings=False,
    source="arXiv:2411.15242",
)

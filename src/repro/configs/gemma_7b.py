from repro.models.config import ModelConfig

# Gemma 7B [arXiv:2403.08295]
# dense: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000, GeGLU,
# head_dim=256 (qkv wider than d_model), sqrt(d) embedding scale.
CONFIG = ModelConfig(
    name="gemma-7b", arch_type="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    mlp_kind="geglu", norm_kind="rmsnorm", pos="rope", rope_theta=10000.0,
    embed_scale=True, tie_embeddings=True,
    source="arXiv:2403.08295",
)

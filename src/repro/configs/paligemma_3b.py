from repro.models.config import ModelConfig

# PaliGemma 3B [arXiv:2407.07726]
# vlm: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.
# SigLIP vision tower is a stub (assignment carve-out): input_specs()
# provides 256 patch embeddings; the image prefix attends bidirectionally
# (prefix-LM) — the partition-aware mask generalizes via prefix_len.
CONFIG = ModelConfig(
    name="paligemma-3b", arch_type="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    mlp_kind="geglu", norm_kind="rmsnorm", pos="rope",
    embed_scale=True, tie_embeddings=True,
    frontend="siglip_stub", prefix_len=256,
    source="arXiv:2407.07726",
)

from repro.models.config import ModelConfig

# OLMoE 1B-7B [arXiv:2409.02060]
# moe: 16L d_model=2048 16H (kv=16), 64 experts top-8, expert d_ff=1024,
# qk-norm, vocab=50304.
CONFIG = ModelConfig(
    name="olmoe-1b-7b", arch_type="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304, blocks=("moe",) * 16,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope", qk_norm=True,
    n_experts=64, top_k=8, expert_d_ff=1024, tie_embeddings=False,
    source="arXiv:2409.02060",
)

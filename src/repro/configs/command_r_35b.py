from repro.models.config import ModelConfig

# Cohere Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]
# dense: 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000, no-bias,
# parallel attention/FFN residual block, LayerNorm.
CONFIG = ModelConfig(
    name="command-r-35b", arch_type="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    mlp_kind="swiglu", norm_kind="layernorm", pos="rope", rope_theta=8e6,
    attn_bias=False, parallel_block=True, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)

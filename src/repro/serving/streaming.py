"""Asynchronous streaming front-end: overlapped host/device serving.

The synchronous engine loop (``ServingEngine.step``) serializes every
tick: plan on host -> run the program -> copy the full ``(rows, V)``
logits to host -> sample -> repeat.  The host is idle while the device
computes and the device is idle while the host copies and samples —
on an edge deployment that dead time, not FLOPs, bounds ITL.

This module is the real serving loop (ROADMAP item 3; JetStream's
``ResultTokens`` idiom — docs/streaming.md has the lifecycle diagram):

* **One small device array per tick.**  The tick's program output is
  reduced ON DEVICE to a ``(n_slots, 4)`` int32 ``ResultTokens`` array
  — ``[token, valid, length, finite]`` per slot (greedy argmax,
  did-this-slot-decode, cache length, NaN-guard verdict) — so the host
  copies ``4 * n_slots`` ints instead of ``rows x vocab`` floats.
* **Double-buffered dispatch.**  Tick N+1 is planned from host state
  and dispatched BEFORE tick N's results arrive; decode tokens that
  are still in flight are spliced in on device from tick N's
  ``ResultTokens`` (``make_result_pack``'s ``merge``), so the device
  never waits for the host round trip.  JAX's async dispatch plus the
  donated-storage chain serializes the ticks on device; the host
  reconciles tick N (one ``jax.device_get`` of the small array) while
  tick N+1 computes.
* **Per-request streams.**  ``submit_stream`` returns an
  ``AsyncIterator[int]`` (``TokenStream``) delivering tokens as their
  tick reconciles; ``cancel`` works mid-flight through the engine's
  zero-leak release path.

Speculative dispatch never changes a token: positions and prompt
prefill advance deterministically on the host, the device argmax is
bit-identical to the host ``np.argmax`` the sync engine samples with
(both take the first maximum), and every speculative K/V write lands
at a position strictly beyond the owner's reconciled frontier — masked
until (idempotently) rewritten, even across page free/rebind, because
a later owner's prefill rewrites every readable position after the
stale write in device order.  State that rewinds (quarantine, restart)
bumps ``RequestState.epoch`` so in-flight rows reconcile as stale and
are discarded.

Overlap requires greedy sampling (the splice re-feeds the device
argmax).  Ticks whose decode set contains a ``temperature > 0``
request fall back to the synchronous path for that tick — tokens still
stream, the pipeline just drains first (depth 1, full logits copy,
host RNG sampling).  Fault injection (``EngineConfig.faults``) also
forces the synchronous path: the chaos blast-radius contracts are
defined per synchronous tick — which is also what makes degraded-mesh
serving (``shard_loss``) safe to stream: the 'degraded' / 'recovered'
tick kinds only ever occur on the synchronous path, so no speculative
row is in flight when a shard dies or when recovery rewinds every
active slot.  A stream crossing a degraded window delivers its first
k tokens from the Segment-Means substitute path and the remainder
exact: the recovery ``reset_for_refill`` rewinds ``generated`` below
the delivered watermark, so re-decoded tokens only reach the stream
past what was already sent (total per stream = ``max_new_tokens``,
all finite).  Control-plane operations that move or free cache state
out of band — preemption, suspend, deadline expiry, snapshot, cancel
— drain the in-flight pipeline first.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..runtime.serve import make_result_pack
from .engine import ServingEngine


@dataclass
class ResultTokens:
    """One in-flight tick: the packed device array plus the host-side
    records needed to reconcile it.

    ``data`` is the ONE device-resident array the tick sends home —
    ``(n_slots, 4)`` int32, per-slot ``[token, valid, length,
    finite]`` (see ``runtime.serve.make_result_pack``).  ``records``
    holds ``(slot, state, epoch)`` for every decode row dispatched in
    the tick: reconciliation walks them, drops rows whose state
    rewound (epoch mismatch) or left the slot (evicted / preempted),
    and advances the rest with the device-sampled token."""
    data: object                       # device (n_slots, 4) int32
    records: list                      # [(slot, RequestState, epoch)]
    kind: str                          # 'packed' | 'decode'
    decode_slots: frozenset            # slots with a decode row this tick
    t_dispatch: float                  # engine-clock dispatch time

    def get(self) -> np.ndarray:
        """The single host copy (blocks until the tick's compute and
        transfer finish)."""
        return np.asarray(jax.device_get(self.data))


class TokenStream:
    """Per-request ``AsyncIterator[int]``: tokens arrive as their tick
    reconciles; iteration ends when the request finishes (``finished``
    holds the reason: ``'length'``, ``'eos'``, ``'cancelled'``,
    ``'deadline'``, ``'max_restarts'``).

    The producer (the engine loop, possibly running in an executor
    thread) calls ``put``/``finish``; consumers either ``async for``
    over the stream or poll ``drain()`` synchronously.  Cross-thread
    wakeups go through ``call_soon_threadsafe``, so the asyncio
    front-end can keep the blocking tick loop off the event loop."""

    def __init__(self, rid: int):
        self.rid = rid
        self._q: deque = deque()
        self._fin: str | None = None
        self._loop = None
        self._event: asyncio.Event | None = None

    # -- producer side (engine loop) -----------------------------------
    def put(self, token: int) -> None:
        self._q.append(token)
        self._wake()

    def finish(self, reason: str) -> None:
        self._fin = reason
        self._wake()

    def _wake(self) -> None:
        if self._event is not None:
            self._loop.call_soon_threadsafe(self._event.set)

    # -- consumer side -------------------------------------------------
    @property
    def finished(self) -> str | None:
        """Finish reason once the request is done, else None."""
        return self._fin

    def drain(self) -> list:
        """Synchronously pop every token delivered so far."""
        out = []
        while self._q:
            out.append(self._q.popleft())
        return out

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        while True:
            if self._q:
                return self._q.popleft()
            if self._fin is not None:
                raise StopAsyncIteration
            if self._event is None:
                self._loop = asyncio.get_running_loop()
                self._event = asyncio.Event()
            self._event.clear()
            await self._event.wait()


class StreamingEngine:
    """Overlapped streaming loop over a ``ServingEngine``.

    Owns the tick pipeline (a deque of in-flight ``ResultTokens``, at
    most ``depth`` deep; depth 2 = classic double buffering) and the
    per-request ``TokenStream`` registry.  The wrapped engine keeps all
    admission / paging / preemption / fault machinery; this class only
    changes WHEN programs run and HOW results come home.

    ``step()`` is one loop iteration: release arrivals, run any
    control-plane work that needs a drained pipeline, admit, dispatch
    one tick if the pipeline has room, reconcile the oldest tick if it
    is full (or nothing could be dispatched), and flush reconciled
    tokens to their streams.  ``run_sync()`` drives to completion;
    ``serve_stream`` is the asyncio front-end."""

    def __init__(self, engine: ServingEngine, *, overlap: bool = True,
                 depth: int = 2):
        self._eng = engine
        self.depth = max(1, int(depth))
        # overlap needs the packed/decode program pair and per-tick
        # chaos semantics off (fault blast radii are defined per
        # synchronous tick)
        self.overlap = bool(overlap and engine.prefill_mode == "packed"
                            and engine._injector is None)
        self._pack, self._merge = make_result_pack(engine.n_slots)
        self._pipe: deque = deque()    # in-flight ResultTokens, FIFO
        self._streams: dict = {}       # rid -> TokenStream
        self._delivered: dict = {}     # rid -> tokens already pushed
        self._token_times: dict = {}   # rid -> [engine-time per token]
        self._zero = jnp.zeros((engine.n_slots, 4), jnp.int32)

    # ------------------------------------------------------------------
    # submission / streams
    # ------------------------------------------------------------------
    @property
    def engine(self) -> ServingEngine:
        return self._eng

    @property
    def has_work(self) -> bool:
        return (bool(self._pipe) or self._eng._sched.has_work
                or bool(self._eng._pending))

    def submit_stream(self, prompt, **kwargs) -> tuple:
        """``ServingEngine.submit`` plus a registered ``TokenStream``;
        returns ``(rid, stream)``."""
        rid = self._eng.submit(prompt, **kwargs)
        stream = TokenStream(rid)
        self._streams[rid] = stream
        self._delivered[rid] = 0
        return rid, stream

    def cancel(self, rid: int) -> bool:
        """Cancel anywhere in the lifecycle — including mid-decode.
        Drains the pipeline first so no in-flight row targets the
        freed slot, then releases through the engine's zero-leak
        path."""
        self.drain()
        ok = self._eng.cancel(rid)
        self._flush_streams()
        return ok

    def preempt(self, rid: int) -> bool:
        self.drain()
        return self._eng.preempt(rid)

    def suspend(self, rid: int) -> bool:
        self.drain()
        return self._eng.suspend(rid)

    def resume(self, rid: int) -> bool:
        return self._eng.resume(rid)

    def snapshot(self):
        self.drain()
        return self._eng.snapshot()

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def step(self) -> str:
        """One streaming-loop iteration.  Returns the dispatched tick
        kind ('packed' / 'decode'), 'reconcile' when the iteration only
        retired an in-flight tick, a synchronous-fallback kind, or
        'idle'."""
        t0 = time.perf_counter()
        kind = self._step_inner()
        if kind != "idle":
            self._eng.stats.loop_wall_s += time.perf_counter() - t0
        return kind

    def _step_inner(self) -> str:
        eng = self._eng
        eng._release_arrivals()
        if eng.stats.t_start is None:
            eng.stats.t_start = eng.now()
        if not self.overlap:
            kind = eng.step()          # sync semantics, still streaming
            self._flush_streams()
            return kind
        # control plane that frees/moves slots out of reconcile order
        # sees a drained pipeline
        if eng._has_deadlines and self._deadline_due():
            self.drain()
            eng._expire()
        if any(st.req.sampling.temperature > 0.0
               for st in eng._sched.active.values()
               if not st.prefilling):
            # host RNG sampling needs the full logits row: fall back to
            # one synchronous tick (depth-1; tokens still stream)
            self.drain()
            kind = eng.step()
            self._flush_streams()
            return kind
        if eng._store is not None and eng._sched.queued and self._pipe:
            # a blocked admission may preempt (device->host page
            # gather): conservative drain keeps spill/rebind races
            # impossible
            self.drain()
        eng._admit_or_preempt()
        dispatched = None
        if len(self._pipe) < self.depth:
            dispatched = self._dispatch()
        if self._pipe and (dispatched is None
                           or len(self._pipe) >= self.depth):
            self._reconcile_one()
            self._flush_streams()
            return dispatched or "reconcile"
        if dispatched is None:
            eng.stats.ticks_idle += 1    # sync paths count their own
            return "idle"
        return dispatched

    def drain(self) -> None:
        """Reconcile every in-flight tick (blocks on the device)."""
        while self._pipe:
            self._reconcile_one()
            self._flush_streams()

    def run_sync(self) -> dict:
        """Drive to completion (the synchronous harness the equivalence
        tests and benches use).  Returns ``ServingEngine.results()``."""
        eng = self._eng
        while True:
            kind = self.step()
            if kind != "idle":
                continue
            if eng._pending:
                before = eng.now()
                dt = eng.next_arrival() - before
                if dt > 0:
                    time.sleep(min(dt, 0.05))
                    if eng.now() <= before:  # injected logical clock
                        eng._t0 -= dt
                continue
            if not self.has_work:
                break
        self.drain()
        self._flush_streams()
        return eng.results()

    # ------------------------------------------------------------------
    # dispatch side
    # ------------------------------------------------------------------
    def _dispatch(self):
        eng = self._eng
        sch = eng._sched
        if any(st.prefilling for st in sch.active.values()):
            return self._dispatch_packed()
        if sch.decoding():
            return self._dispatch_decode()
        return None

    def _can_decode(self, st) -> bool:
        """A slot may not overrun its generation budget with in-flight
        rows; EOS overruns (at most one row, unpredictable by design)
        reconcile as stale instead."""
        return len(st.generated) + st.inflight < st.req.max_new_tokens

    def _spec_token(self, st, tok, src, i) -> None:
        """Pick row i's token source: the previous in-flight tick's
        on-device sample when one exists, else the host-known value
        (first decode after a reconcile, or the rewind re-feed)."""
        if st.inflight > 0:
            prev = self._pipe[-1]
            assert st.slot in prev.decode_slots, (
                "double-buffer gap: in-flight decode row without a "
                "previous-tick sample")
            src[i] = st.slot
        else:
            tok[i] = st.next_token

    def _dispatch_packed(self):
        eng = self._eng
        sch = eng._sched
        t0 = time.perf_counter()
        decode, prefill = sch.plan_tick(eng.token_budget)
        decode = [st for st in decode if self._can_decode(st)]
        if not decode and not prefill:
            return None
        tb = eng.token_budget
        tok = np.zeros(tb, np.int32)
        slot = np.full(tb, -1, np.int32)
        pos = np.full(tb, -1, np.int32)
        off = np.full(tb, -1, np.int32)
        pre = np.zeros(tb, np.int32)
        src = np.full(tb, -1, np.int32)
        lengths = np.zeros(eng.n_slots, np.int32)
        records = []
        i = 0
        for st in decode:
            p = st.pos + st.inflight
            self._spec_token(st, tok, src, i)
            slot[i] = st.slot
            pos[i] = off[i] = p
            lengths[st.slot] = p + 1
            records.append((st.slot, st, st.epoch))
            st.inflight += 1
            i += 1
        n_dec = i
        n_prefill = 0
        for st, take in prefill:
            o = st.nprefilled
            tok[i:i + take] = st.req.prompt[o:o + take]
            slot[i:i + take] = st.slot
            pos[i:i + take] = np.arange(o, o + take)
            off[i:i + take] = o
            pre[i:i + take] = 1
            i += take
            n_prefill += take
            # prefill progress is host-deterministic: advance at
            # dispatch so the NEXT tick plans past it (the rewind
            # re-feed token is host-known, so a request can finish
            # prefill and start decoding with zero pipeline stalls)
            st.nprefilled += take
            if not st.prefilling:
                st.begin_decode()
        # the packed program's LM head covers the static decode prefix
        # (min(n_slots, token_budget) rows; decode rows pack first)
        n_rows = min(eng.n_slots, tb)
        is_dec = np.zeros(n_rows, np.int32)
        is_dec[:n_dec] = 1
        prev_data = self._pipe[-1].data if self._pipe else self._zero
        eng.stats.host_busy_s += time.perf_counter() - t0
        tok_dev = self._merge(jnp.asarray(tok), jnp.asarray(src),
                              prev_data)
        logits, eng._kv.storage = eng._packed(
            eng.params, eng._kv.storage, tok_dev, jnp.asarray(slot),
            jnp.asarray(pos), jnp.asarray(off), jnp.asarray(pre),
            *eng._maps())
        data = self._pack(logits, jnp.asarray(slot[:n_rows].copy()),
                          jnp.asarray(is_dec), jnp.asarray(lengths))
        t1 = time.perf_counter()
        self._push(data, records, "packed")
        eng.stats.packed_ticks += 1
        eng.stats.packed_decode_tokens += n_dec
        eng.stats.packed_prefill_tokens += n_prefill
        eng.stats.prefill_tokens += n_prefill
        eng.stats.host_busy_s += time.perf_counter() - t1
        return "packed"

    def _dispatch_decode(self):
        eng = self._eng
        sch = eng._sched
        t0 = time.perf_counter()
        decode = [st for st in sch.decoding() if self._can_decode(st)]
        if not decode:
            return None
        B = eng.n_slots
        tok = np.zeros(B, np.int32)
        pos = np.full(B, -1, np.int32)
        src = np.full(B, -1, np.int32)
        is_dec = np.zeros(B, np.int32)
        lengths = np.zeros(B, np.int32)
        records = []
        for st in decode:
            p = st.pos + st.inflight
            self._spec_token(st, tok, src, st.slot)
            pos[st.slot] = p
            is_dec[st.slot] = 1
            lengths[st.slot] = p + 1
            records.append((st.slot, st, st.epoch))
            st.inflight += 1
        prev_data = self._pipe[-1].data if self._pipe else self._zero
        eng.stats.host_busy_s += time.perf_counter() - t0
        tok_dev = self._merge(jnp.asarray(tok), jnp.asarray(src),
                              prev_data)
        logits, eng._kv.storage = eng._step(
            eng.params, eng._kv.storage, tok_dev, jnp.asarray(pos),
            *eng._maps())
        data = self._pack(logits, jnp.arange(B, dtype=jnp.int32),
                          jnp.asarray(is_dec), jnp.asarray(lengths))
        t1 = time.perf_counter()
        self._push(data, records, "decode")
        eng.stats.decode_steps += 1
        sch.note_decode()
        eng.stats.host_busy_s += time.perf_counter() - t1
        return "decode"

    def _push(self, data, records, kind: str) -> None:
        eng = self._eng
        eng.stats.occupancy.append(
            len(eng._sched.active) / eng.n_slots)
        self._pipe.append(ResultTokens(
            data=data, records=records, kind=kind,
            decode_slots=frozenset(s for s, _, _ in records),
            t_dispatch=eng.now()))

    # ------------------------------------------------------------------
    # reconcile side
    # ------------------------------------------------------------------
    def _reconcile_one(self) -> None:
        eng = self._eng
        tick = self._pipe.popleft()
        data = tick.get()              # THE host copy; blocks on device
        t0 = time.perf_counter()
        now = eng.now()
        eng.stats.step_latency.append(now - tick.t_dispatch)
        for slot, st, epoch in tick.records:
            st.inflight -= 1
            if st.epoch != epoch:
                continue               # rewound (quarantine/restart)
            if eng._sched.active.get(slot) is not st:
                continue               # evicted / preempted / cancelled
            token, valid, _, finite = data[slot]
            if not valid:
                continue
            if eng._nan_guard and not finite:
                eng._quarantine(st)    # bumps epoch: later rows stale
                continue
            eng._advance_token(st, int(token), now)
        eng.stats.t_end = eng.now()
        eng.stats.host_busy_s += time.perf_counter() - t0

    def _deadline_due(self) -> bool:
        now = self._eng.now()
        return any(r.deadline is not None and now >= r.deadline
                   for r in self._eng._live_requests())

    # ------------------------------------------------------------------
    # stream delivery
    # ------------------------------------------------------------------
    def _flush_streams(self) -> None:
        eng = self._eng
        if not self._streams:
            return
        done = []
        now = eng.now()
        for rid, stream in self._streams.items():
            fin = None
            st = eng._results.get(rid)
            if st is not None:
                fin = ("eos" if (st.req.eos_id is not None
                                 and st.generated
                                 and st.generated[-1] == st.req.eos_id)
                       else "length")
            elif rid in eng._failed:
                fin = eng._failed[rid]
                st = None
            else:
                st = next((s for s in eng._sched.active.values()
                           if s.req.rid == rid), None)
            if st is not None:
                sent = self._delivered.get(rid, 0)
                fresh = st.generated[sent:]
                if fresh:
                    for t in fresh:
                        stream.put(int(t))
                    self._delivered[rid] = sent + len(fresh)
                    eng.stats.tokens_streamed += len(fresh)
                    self._token_times.setdefault(rid, []).extend(
                        [now] * len(fresh))
            if fin is not None:
                stream.finish(fin)
                done.append(rid)
        for rid in done:
            del self._streams[rid]
            self._delivered.pop(rid, None)

    def itl_samples(self) -> dict:
        """{rid: [inter-token latencies]} in engine-clock units, from
        the stream delivery timestamps (tokens delivered in the same
        flush contribute zero — they arrived in one reconcile)."""
        return {rid: [b - a for a, b in zip(ts, ts[1:])]
                for rid, ts in self._token_times.items()
                if len(ts) > 1}


async def serve_stream(seng: StreamingEngine, requests: list,
                       *, idle_sleep: float = 0.002) -> dict:
    """Asyncio front-end: submit every request (dicts of
    ``submit_stream`` kwargs — typically with Poisson ``arrival``
    times), run the blocking tick loop in the default executor so
    consumer coroutines interleave with device work, and collect each
    stream.  Returns ``{rid: {"tokens": [...], "times": [...],
    "finished": reason}}`` with wall-clock delivery times."""
    loop = asyncio.get_running_loop()
    out: dict = {}

    async def consume(rid: int, stream: TokenStream) -> None:
        toks, times = [], []
        async for t in stream:
            toks.append(t)
            times.append(time.perf_counter())
        out[rid] = {"tokens": toks, "times": times,
                    "finished": stream.finished}

    tasks = []
    for kw in requests:
        rid, stream = seng.submit_stream(**kw)
        tasks.append(asyncio.ensure_future(consume(rid, stream)))
    while seng.has_work:
        kind = await loop.run_in_executor(None, seng.step)
        if kind == "idle":
            await asyncio.sleep(idle_sleep)
    seng.drain()
    seng._flush_streams()
    await asyncio.gather(*tasks)
    return out

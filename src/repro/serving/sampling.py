"""Per-request token sampling on final logits.

The engine samples on the host: on a single-process mesh,
``np.asarray`` of the global logits array materialises the full (B, V)
rows even when the LM head is vocab-sharded over 'model', so
greedy/temperature/top-k all see the whole vocabulary regardless of the
embedding sharding.  Each request carries its own numpy Generator
seeded at submit time, so sampling is reproducible under any
interleaving of requests through the slot pool — the property the
6-requests/4-slots equivalence test leans on.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """temperature == 0 -> greedy; top_k == 0 -> full vocabulary."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def make_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)


def sample_token(logits: np.ndarray, sp: SamplingParams,
                 rng: np.random.Generator) -> int:
    """One token id from a (V,) float logits row."""
    if sp.temperature <= 0.0:
        return int(np.argmax(logits))
    z = logits.astype(np.float64) / sp.temperature
    if sp.top_k > 0:
        k = min(sp.top_k, z.shape[0])
        kth = np.partition(z, -k)[-k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(z.shape[0], p=p))

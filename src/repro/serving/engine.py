"""Continuous-batching serving engine over the sequence-sharded runtime.

Request lifecycle (docs/serving.md has the full tour)::

    submit ──> [FIFO queue] ──> admit into a free slot (host-side)
    ──> PACKED PREFILL: each engine tick, every live decode token plus
    prompt-chunk tokens from every mid-prefill request pack into ONE
    flat token batch, consumed by one compiled program (cost ∝ real
    tokens) ──> rewind to pos = len(prompt) - 1 ──> decode (packed
    ticks while anything is prefilling, the plain per-slot decode
    program otherwise) ──> host-side sampling ──> evict on EOS /
    max-tokens ──> slot freed, mid-flight.

Engine tick programs (compiled lazily, cached by ``(kind,
token_budget)`` so alternating tick kinds never retrace):

  * ``packed`` — the default hot path: one flat ``(token_budget,)``
    batch of mixed work per tick, planned Sarathi-style by
    ``FifoScheduler.plan_tick`` (decodes first, remaining budget
    filled with prompt tokens across ALL mid-prefill requests).
    Per-tick cost scales with the REAL packed tokens instead of
    ``n_slots × chunk_len`` — under saturation this out-amortizes even
    the gang flush, which the chunked engine could not.
  * ``decode`` — batch = n_slots single-token decode with a (B,) pos
    vector; used for ticks with nothing prefilling (every request at
    its own depth).
  * ``chunk``  — the ``prefill_mode='chunked'`` oracle: batch =
    n_slots, up to chunk_len prompt tokens per row at per-row runtime
    offsets, interleaved with decodes under ``decode_per_prefill``.
  * the legacy ``padded`` trio (flush + grow + insert).

The admission rewind: prefill programs return no sampled tokens; when
a request's last prompt token lands, the slot starts decoding at
``pos = len(prompt) - 1``, re-feeding the last prompt token.  That
first decode rewrites the token's K/V row in place (an idempotent
rewrite — the computation is identical) and yields exactly the
teacher-forced next-token logits, in the configured decode mode.  TTFT
is measured to the first token sampled from those logits.  Packed and
chunk attention are exact (cross-shard stat combine), so engine output
is token-identical to sequential serving in every mode.

In ``prism`` decode mode the prefill programs also accumulate the
Segment-Means state (kz/vz + per-request counts gz + running sums
zsum) over REAL prompt columns only — short prompts no longer fold pad
columns into the remote-means approximation, which the padded flush
admission used to do (the old wart, kept reproducible via
``prefill_mode='padded'``).

``prefill_mode='chunked'`` (the PR-4 hot path) and
``prefill_mode='padded'`` (the PR-2 three-program admission) survive
as selectable oracles and benchmark baselines; docs/serving.md
quantifies the differences.
"""
from __future__ import annotations

import functools
import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..core.protocol import PrismConfig
from ..models.config import ModelConfig
from ..runtime.serve import (ServeHParams, cache_specs, grow_cache,
                             init_cache, insert_cache_row, make_layout,
                             make_chunk_prefill_step, make_packed_step,
                             make_prefill_step, make_serve_step)
from .sampling import SamplingParams, sample_token
from .scheduler import EngineStats, FifoScheduler, Request


class ServingEngine:
    """Multiplexes independent requests through a fixed pool of decode
    slots backed by one batched, sequence-sharded KV cache."""

    def __init__(self, cfg: ModelConfig, mesh, params, *,
                 n_slots: int, prefill_len: int, max_cache: int,
                 hp: ServeHParams = ServeHParams(),
                 prism: PrismConfig | None = None,
                 decode_per_prefill: int = 4, gang: bool = False,
                 chunk_len: int = 64, prefill_mode: str = "packed",
                 token_budget: int | None = None,
                 pad_id: int = 0, clock=time.monotonic):
        if prefill_mode not in ("packed", "chunked", "padded"):
            raise ValueError(f"prefill_mode {prefill_mode!r} not in "
                             "('packed', 'chunked', 'padded')")
        if prism is None:
            prism = PrismConfig(
                P=1, cr=hp.means_cr,
                mode="prism" if hp.decode_mode == "prism" else "voltage")
        unsupported = {k for k in cfg.block_kinds
                       if k in ("mlstm", "slstm", "mamba", "attn_local")}
        if unsupported:
            # The admission scheme relies on the cache being addressed
            # purely by global position: right-padded prefill leaves the
            # real rows exact, and the rewind rewrite is idempotent.
            # Recurrent SSM state consumes pad tokens (and the rewind
            # would double-feed the last prompt token), and the ring
            # window cache holds the padded tail, so those blocks need a
            # state-snapshot admission path — future work.  The static
            # serve path (repro.launch.serve without --engine) still
            # covers these architectures.
            raise ValueError(
                f"ServingEngine does not support block kinds "
                f"{sorted(unsupported)} (arch {cfg.name!r}); only "
                "global-attention caches (attn/moe/shared_attn) admit "
                "correctly")
        if cfg.arch_type == "vlm" or cfg.frontend:
            # those prefill signatures require an 'embeds' input the
            # engine's token-only admission path never builds
            raise ValueError(
                f"ServingEngine serves token prompts only; arch "
                f"{cfg.name!r} (arch_type={cfg.arch_type!r}, "
                f"frontend={cfg.frontend!r}) needs embedding inputs")
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.n_slots, self.prefill_len = n_slots, prefill_len
        self.prefill_mode = prefill_mode
        self.chunk_len = max(1, min(chunk_len, prefill_len))
        if token_budget is None:
            # every decoding slot's token plus one chunk's worth of
            # prompt tokens — the smallest budget that keeps a full
            # decode fleet moving while still packing prefill work
            token_budget = n_slots + self.chunk_len
        if token_budget < n_slots:
            raise ValueError(
                f"token_budget {token_budget} < n_slots {n_slots}: "
                "every decoding slot needs its token in each tick")
        self.token_budget = int(token_budget)
        self.pad_id, self._clock = pad_id, clock
        self._hp, self._prism, self._max_cache = hp, prism, max_cache

        self.layout = make_layout(cfg, mesh, n_slots, max_cache, hp,
                                  prefill_len)
        # pin the decode-layout cache sharding on every path that feeds
        # the step functions (their donated args reject resharding)
        self._cache_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            cache_specs(cfg, self.layout, hp))
        # compiled-program cache: one entry per (kind, token_budget),
        # so ticks that alternate program kinds (packed <-> decode)
        # reuse the SAME jitted callable and never retrace —
        # runtime.serve.trace_counts pins this in the tests
        self._programs: dict = {}
        self._step = self._program("decode")
        if prefill_mode == "packed":
            self._packed = self._program("packed", self.token_budget)
        elif prefill_mode == "chunked":
            self._chunk = self._program("chunk")
        else:
            self._prefill = self._program("padded_prefill")
            self._grow = self._program("grow")
            self._insert = self._program("insert")
        self._cache = jax.device_put(
            init_cache(cfg, self.layout, n_slots, hp), self._cache_sh)

        self._sched = FifoScheduler(n_slots,
                                    decode_per_prefill=decode_per_prefill,
                                    gang=gang)
        self.stats = EngineStats(n_slots=n_slots)
        self._pending: list = []       # heap of (arrival, rid, Request)
        self._results: dict = {}       # rid -> RequestState
        self._next_rid = 0
        self._t0 = None                # clock origin (first submit/run)

    # ------------------------------------------------------------------
    # compiled-program cache
    # ------------------------------------------------------------------
    def _program(self, kind: str, token_budget: int | None = None):
        """Build-or-fetch one of the engine's compiled step programs.

        Keyed by ``(kind, token_budget)``: repeated requests return the
        SAME jitted callable, so however the engine's ticks alternate
        (packed while anything prefills, plain decode otherwise) each
        program traces at most once per engine — the regression test in
        ``tests/test_packed_step.py`` asserts the bound via the
        trace-time counters in ``repro.runtime.serve``."""
        key = (kind, token_budget)
        if key in self._programs:
            return self._programs[key]
        cfg, mesh, params, hp = self.cfg, self.mesh, self.params, self._hp
        kw = dict(batch=self.n_slots, cap=self._max_cache,
                  prefill_len=self.prefill_len, hp=hp)
        if kind == "decode":
            prog, lay, _, _ = make_serve_step(cfg, mesh, params, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "packed":
            prog, lay, _, _ = make_packed_step(
                cfg, mesh, params, token_budget=token_budget, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "chunk":
            prog, lay, _ = make_chunk_prefill_step(
                cfg, mesh, params, chunk_len=self.chunk_len, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "padded_prefill":
            # legacy padded admission (make_prefill_step re-derives
            # PrismConfig.P from the layout's n_seq; only mode/cr of
            # ``prism`` matter here)
            prog, lay_p, _, _ = make_prefill_step(
                cfg, mesh, params, self._prism, batch=self.n_slots,
                n=self.prefill_len, hp=hp)
            assert lay_p == self._prefill_layout(), (lay_p, self.layout)
        elif kind == "grow":
            prog = jax.jit(
                functools.partial(grow_cache,
                                  lay_from=self._prefill_layout(),
                                  lay_to=self.layout),
                out_shardings=self._cache_sh)
        elif kind == "insert":
            prog = jax.jit(insert_cache_row, donate_argnums=(0,),
                           out_shardings=self._cache_sh)
        else:
            raise ValueError(kind)
        self._programs[key] = prog
        return prog

    def _prefill_layout(self):
        """The padded-admission prefill layout (cap == prefill_len) —
        derived, so 'grow' never depends on 'padded_prefill' having
        been built first."""
        return make_layout(self.cfg, self.mesh, self.n_slots,
                           self.prefill_len, self._hp)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def submit(self, prompt, *, max_new_tokens: int, eos_id=None,
               sampling: SamplingParams = SamplingParams(),
               arrival: float | None = None) -> int:
        """Queue one request.  ``arrival`` (engine-relative seconds) may
        lie in the future — the run loop holds the request back until
        the clock passes it, which is how Poisson traces are replayed.
        """
        prompt = tuple(int(t) for t in prompt)
        if not 1 <= len(prompt) <= self.prefill_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, {self.prefill_len}]")
        if len(prompt) + max_new_tokens > self.layout.cap:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new_tokens} exceeds "
                f"cache capacity {self.layout.cap}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, sampling=sampling,
                      arrival=self.now() if arrival is None else arrival)
        # always route through the arrival-ordered pending heap so a
        # late submit with an already-past arrival cannot jump ahead of
        # earlier arrivals still waiting to be released (FIFO by
        # arrival time; rid breaks ties in submit order)
        heapq.heappush(self._pending, (req.arrival, rid, req))
        self._release_arrivals()
        return rid

    def _release_arrivals(self):
        now = self.now()
        while self._pending and self._pending[0][0] <= now:
            self._sched.submit(heapq.heappop(self._pending)[2])
        self._sched.drain = not self._pending

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest not-yet-released request —
        what an external drive loop (logical-clock benchmarks) jumps
        the clock to when the engine reports 'idle'."""
        return self._pending[0][0] if self._pending else None

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------
    def step(self) -> str:
        """Run one scheduler decision: a packed tick (chunked mode: a
        prefill chunk; padded mode: an admission flush), a decode step,
        or nothing ('idle').  Returns which.  In packed mode a tick
        with nothing prefilling falls through to the plain decode
        program — both programs live in the compiled-program cache, so
        alternating kinds never retrace."""
        sch = self._sched
        self._release_arrivals()
        if self.stats.t_start is None:
            self.stats.t_start = self.now()

        if self.prefill_mode == "padded":
            if sch.want_prefill():
                return self._padded_flush()
        elif self.prefill_mode == "chunked":
            if sch.want_admit():
                sch.admit(self.now())      # host-side: assign slots only
            if sch.want_chunk():
                return self._chunk_step()
        else:                              # packed: one program per tick
            if sch.want_admit():
                sch.admit(self.now())      # host-side: assign slots only
            if any(st.prefilling for st in sch.active.values()):
                return self._packed_tick()

        decoding = sch.decoding()
        if decoding:
            tok = np.zeros(self.n_slots, np.int32)
            pos = np.full(self.n_slots, -1, np.int32)
            for st in decoding:
                tok[st.slot] = st.next_token
                pos[st.slot] = st.pos
            t0 = self.now()
            logits, self._cache = self._step(
                self.params, self._cache, jnp.asarray(tok), jnp.asarray(pos))
            rows = np.asarray(jax.device_get(logits))
            now = self.now()
            self.stats.step_latency.append(now - t0)
            self.stats.occupancy.append(len(sch.active) / self.n_slots)
            self.stats.decode_steps += 1
            for st in decoding:
                self._advance_decode(st, rows[st.slot], now)
            sch.note_decode()
            self.stats.t_end = self.now()
            return "decode"
        return "idle"

    def _advance_decode(self, st, logits_row, now):
        """Sample one token for a decode-phase request and advance /
        evict it — shared by the decode step and the packed tick."""
        t = sample_token(logits_row, st.req.sampling, st.rng)
        st.generated.append(t)
        self.stats.generated_tokens += 1
        if st.ttft is None:
            st.ttft = now - st.req.arrival
            self.stats.ttft.append(st.ttft)
        st.pos += 1
        st.next_token = t
        if st.finished():
            self._sched.evict(st, now)
            self._results[st.req.rid] = st
            self.stats.completed += 1

    def _packed_tick(self) -> str:
        """ONE compiled program for the whole engine tick: every live
        decode token plus prompt-chunk tokens from every mid-prefill
        request, flattened into a (token_budget,) ragged batch (dead
        tail entries pass slot = -1).  Decode rows are sampled from the
        returned logits; prefill rows only advance their request's
        offset (the rewind then re-feeds the last prompt token, exactly
        as in chunked mode, so output stays token-identical)."""
        sch = self._sched
        decode, prefill = sch.plan_tick(self.token_budget)
        tb = self.token_budget
        tok = np.zeros(tb, np.int32)
        slot = np.full(tb, -1, np.int32)
        pos = np.full(tb, -1, np.int32)
        off = np.full(tb, -1, np.int32)
        pre = np.zeros(tb, np.int32)
        i = 0
        dec_rows = []
        for st in decode:
            tok[i], slot[i] = st.next_token, st.slot
            pos[i] = off[i] = st.pos
            dec_rows.append((i, st))
            i += 1
        n_prefill = 0
        for st, take in prefill:
            o = st.nprefilled
            tok[i:i + take] = st.req.prompt[o:o + take]
            slot[i:i + take] = st.slot
            pos[i:i + take] = np.arange(o, o + take)
            off[i:i + take] = o
            pre[i:i + take] = 1
            i += take
            n_prefill += take

        t0 = self.now()
        logits, self._cache = self._packed(
            self.params, self._cache, jnp.asarray(tok), jnp.asarray(slot),
            jnp.asarray(pos), jnp.asarray(off), jnp.asarray(pre))
        rows = np.asarray(jax.device_get(logits))
        now = self.now()
        self.stats.step_latency.append(now - t0)
        self.stats.occupancy.append(len(sch.active) / self.n_slots)
        self.stats.packed_ticks += 1
        self.stats.packed_decode_tokens += len(dec_rows)
        self.stats.packed_prefill_tokens += n_prefill
        self.stats.prefill_tokens += n_prefill
        for j, st in dec_rows:
            self._advance_decode(st, rows[j], now)
        for st, take in prefill:
            st.nprefilled += take
            if not st.prefilling:
                st.begin_decode()          # rewind: re-feed last token
        self.stats.t_end = self.now()
        return "packed"

    def _chunk_step(self) -> str:
        """Advance EVERY mid-prefill request by one chunk (each at its
        own offset) in a single compiled call.  The empty-states guard
        keeps the no-mid-prefill-no-launch invariant local (the
        scheduler's ``want_chunk`` enforces it on the step() path; a
        direct caller gets the same no-op), and the real-vs-padded
        chunk-token split is tracked so ``EngineStats.summary`` can
        report how much of each launched ``(n_slots, chunk_len)``
        program was live work — the waste the FLOP model exposed and
        packed mode eliminates."""
        sch = self._sched
        states = sch.prefilling()
        if not states:                     # nothing mid-prefill: no-op
            return "idle"
        c = self.chunk_len
        tokens = np.full((self.n_slots, c), self.pad_id, np.int32)
        off = np.full(self.n_slots, -1, np.int32)
        nreal = np.zeros(self.n_slots, np.int32)
        for st in states:
            take = min(c, len(st.req.prompt) - st.nprefilled)
            tokens[st.slot, :take] = st.req.prompt[
                st.nprefilled:st.nprefilled + take]
            off[st.slot] = st.nprefilled
            nreal[st.slot] = take
        self._cache = self._chunk(self.params, self._cache,
                                  jnp.asarray(tokens), jnp.asarray(off),
                                  jnp.asarray(nreal))
        for st in states:
            st.nprefilled += int(nreal[st.slot])
            if not st.prefilling:
                st.begin_decode()          # rewind: re-feed last token
        sch.note_chunk()
        real = int(nreal.sum())
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += real
        self.stats.chunk_tokens_real += real
        self.stats.chunk_tokens_padded += self.n_slots * c - real
        self.stats.t_end = self.now()
        return "prefill"

    def _padded_flush(self) -> str:
        """Legacy admission: right-pad every admitted prompt to
        ``prefill_len``, one monolithic prefill, grow + splice each row
        into its slot, start decoding at the rewind position."""
        sch = self._sched
        batch = np.full((self.n_slots, self.prefill_len), self.pad_id,
                        np.int32)
        states = sch.admit(self.now())
        for i, st in enumerate(states):
            batch[i, :len(st.req.prompt)] = st.req.prompt
        _, fresh = self._prefill(self.params, {"tokens":
                                               jnp.asarray(batch)})
        grown = self._grow(fresh)
        for i, st in enumerate(states):
            self._cache = self._insert(self._cache, grown,
                                       jnp.asarray(i, jnp.int32),
                                       jnp.asarray(st.slot, jnp.int32))
            st.begin_decode()
            self.stats.prefill_tokens += len(st.req.prompt)
        self.stats.prefills += 1
        self.stats.t_end = self.now()
        return "prefill"

    # ------------------------------------------------------------------
    # drive to completion
    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Step until every submitted request (including future
        arrivals) has finished.  Returns {rid: [generated token ids]}."""
        while True:
            kind = self.step()
            if kind != "idle":
                continue
            if self._pending:
                # nothing runnable until the next arrival — wait it
                # out.  An injected clock that doesn't tick with wall
                # time (e.g. a logical StepClock) is fast-forwarded to
                # the arrival instead, so run() terminates under both.
                before = self.now()
                dt = self.next_arrival() - before
                if dt > 0:
                    time.sleep(min(dt, 0.05))
                    if self.now() <= before:
                        self._t0 -= dt
                continue
            if not self._sched.has_work:
                break
        return self.results()

    def results(self) -> dict:
        return {rid: list(st.generated)
                for rid, st in sorted(self._results.items())}

    def request_stats(self) -> dict:
        return {rid: {"ttft_s": st.ttft,
                      "latency_s": (st.t_finish - st.req.arrival
                                    if st.t_finish is not None else None),
                      "tokens": len(st.generated)}
                for rid, st in sorted(self._results.items())}

"""Continuous-batching serving engine over the sequence-sharded runtime.

Request lifecycle (docs/serving.md has the full tour)::

    submit ──> [FIFO queue] ──> admit into a free slot (host-side)
    ──> PACKED PREFILL: each engine tick, every live decode token plus
    prompt-chunk tokens from every mid-prefill request pack into ONE
    flat token batch, consumed by one compiled program (cost ∝ real
    tokens) ──> rewind to pos = len(prompt) - 1 ──> decode (packed
    ticks while anything is prefilling, the plain per-slot decode
    program otherwise) ──> host-side sampling ──> evict on EOS /
    max-tokens ──> slot freed, mid-flight.

Engine tick programs (compiled lazily, cached by ``(kind,
token_budget)`` so alternating tick kinds never retrace):

  * ``packed`` — the default hot path: one flat ``(token_budget,)``
    batch of mixed work per tick, planned Sarathi-style by
    ``FifoScheduler.plan_tick`` (decodes first, remaining budget
    filled with prompt tokens across ALL mid-prefill requests).
    Per-tick cost scales with the REAL packed tokens instead of
    ``n_slots × chunk_len`` — under saturation this out-amortizes even
    the gang flush, which the chunked engine could not.
  * ``decode`` — batch = n_slots single-token decode with a (B,) pos
    vector; used for ticks with nothing prefilling (every request at
    its own depth).
  * ``chunk``  — the ``prefill_mode='chunked'`` oracle: batch =
    n_slots, up to chunk_len prompt tokens per row at per-row runtime
    offsets, interleaved with decodes under ``decode_per_prefill``.
  * the legacy ``padded`` flush (prefill at decode capacity + one
    row splice per admitted request, via ``KVCache.insert_row``).

The admission rewind: prefill programs return no sampled tokens; when
a request's last prompt token lands, the slot starts decoding at
``pos = len(prompt) - 1``, re-feeding the last prompt token.  That
first decode rewrites the token's K/V row in place (an idempotent
rewrite — the computation is identical) and yields exactly the
teacher-forced next-token logits, in the configured decode mode.  TTFT
is measured to the first token sampled from those logits.  Packed and
chunk attention are exact (cross-shard stat combine), so engine output
is token-identical to sequential serving in every mode.

In ``prism`` decode mode the prefill programs also accumulate the
Segment-Means state (kz/vz + per-request counts gz + running sums
zsum) over REAL prompt columns only — short prompts no longer fold pad
columns into the remote-means approximation, which the padded flush
admission used to do (the old wart, kept reproducible via
``prefill_mode='padded'``).

``prefill_mode='chunked'`` (the PR-4 hot path) and
``prefill_mode='padded'`` (the PR-2 three-program admission) survive
as selectable oracles and benchmark baselines; docs/serving.md
quantifies the differences.
"""
from __future__ import annotations

import copy
import heapq
import math
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.protocol import PrismConfig
from ..models.config import ModelConfig
from ..runtime.faults import FaultInjector, FaultPlan
from ..runtime.offload import KVStore
from ..runtime.paging import AdmitPlan, make_paged_layout
from ..runtime.replica import MeansReplica
from ..runtime.serve import (ServeHParams, _paged_placement, make_layout,
                             make_chunk_prefill_step, make_kv_cache,
                             make_packed_step, make_prefill_step,
                             make_serve_step, seq_shards)
from .sampling import SamplingParams, sample_token
from .scheduler import EngineStats, FifoScheduler, Request, RequestState


@dataclass(frozen=True)
class EngineConfig:
    """Validated engine configuration — the single construction path
    for ``ServingEngine`` (launch, examples, and benches all build one
    of these; the legacy kwarg constructor is a thin shim over it).

    ``__post_init__`` normalizes the derived fields so an EngineConfig
    is always self-consistent by the time the engine sees it:
    ``chunk_len`` clamps to ``[1, prefill_len]``, ``token_budget``
    defaults to ``n_slots + chunk_len`` (the smallest budget that keeps
    a full decode fleet moving while packing prefill work),
    ``prefill_mode='padded'`` forces the dense rowset (the legacy
    flush+insert admission predates paging), and ``prefix_cache``
    defaults on exactly where it is sound: the paged exact engine
    (paged prism keeps the aligned Segment-Means placement, where a
    partial page set never covers a position prefix)."""
    n_slots: int
    prefill_len: int
    max_cache: int
    hp: ServeHParams = ServeHParams()
    prism: PrismConfig | None = None
    decode_per_prefill: int = 4
    gang: bool = False
    chunk_len: int = 64
    prefill_mode: str = "packed"
    token_budget: int | None = None
    pad_id: int = 0
    paged: bool = True                 # page-table cache (the default)
    page_tokens: int | None = None     # page size in token positions
    n_pages: int | None = None         # pool size (default: slot parity)
    prefix_cache: bool | None = None   # shared-prefix COW reuse
    offload: bool = False              # host KVStore tier + preemption
    offload_bytes: int | None = None   # store capacity (None = unbounded)
    faults: FaultPlan | None = None    # seeded chaos plan (None = off)
    max_restarts: int = 3              # reset_for_refill bound per request
    degraded_grace: int = 2            # means-substituted ticks per loss
    replica_refresh: int = 16          # standby staleness refresh period
    restore_retries: int = 2           # KVStore.get retries before refill
    restore_backoff_s: float = 0.0     # exponential backoff base (sleep)

    def __post_init__(self):
        if self.max_restarts < 1:
            raise ValueError(f"max_restarts {self.max_restarts} < 1")
        if self.degraded_grace < 0:
            raise ValueError(f"degraded_grace {self.degraded_grace} < 0")
        if self.replica_refresh < 1:
            raise ValueError(
                f"replica_refresh {self.replica_refresh} < 1")
        if self.restore_retries < 0:
            raise ValueError(
                f"restore_retries {self.restore_retries} < 0")
        if self.restore_backoff_s < 0.0:
            raise ValueError(
                f"restore_backoff_s {self.restore_backoff_s} < 0")
        if self.faults is not None and not isinstance(self.faults,
                                                      FaultPlan):
            raise ValueError(
                f"faults must be a FaultPlan, got "
                f"{type(self.faults).__name__}")
        if self.prefill_mode not in ("packed", "chunked", "padded"):
            raise ValueError(f"prefill_mode {self.prefill_mode!r} not in "
                             "('packed', 'chunked', 'padded')")
        if self.n_slots < 1:
            raise ValueError(f"n_slots {self.n_slots} < 1")
        if not 1 <= self.prefill_len <= self.max_cache:
            raise ValueError(
                f"prefill_len {self.prefill_len} not in "
                f"[1, max_cache={self.max_cache}]")
        set_ = lambda k, v: object.__setattr__(self, k, v)
        set_("chunk_len",
             max(1, min(self.chunk_len, self.prefill_len)))
        if self.token_budget is None:
            set_("token_budget", self.n_slots + self.chunk_len)
        if self.token_budget < self.n_slots:
            raise ValueError(
                f"token_budget {self.token_budget} < n_slots "
                f"{self.n_slots}: every decoding slot needs its token "
                "in each tick")
        if self.prefill_mode == "padded" and self.paged:
            set_("paged", False)       # legacy flush+insert admission
        ok_prefix = self.paged and self.hp.decode_mode == "exact"
        if self.prefix_cache is None:
            set_("prefix_cache", ok_prefix)
        elif self.prefix_cache and not ok_prefix:
            raise ValueError(
                "prefix_cache requires the paged cache in exact decode "
                f"mode (paged={self.paged}, "
                f"decode_mode={self.hp.decode_mode!r})")
        if self.offload:
            if not self.paged:
                raise ValueError(
                    "offload requires the paged cache "
                    f"(paged={self.paged}, prefill_mode="
                    f"{self.prefill_mode!r}): spill/restore moves pages, "
                    "not dense rows")
            if self.gang:
                raise ValueError(
                    "offload/preemption is incompatible with gang "
                    "(static batching) admission")
        if self.prism is None:
            set_("prism", PrismConfig(
                P=1, cr=self.hp.means_cr,
                mode="prism" if self.hp.decode_mode == "prism"
                else "voltage"))


@dataclass
class EngineSnapshot:
    """Crash-consistent journal of one engine's complete serving state,
    taken between steps (``ServingEngine.snapshot``).  Host-side only:
    every live slot's cache footprint rides as the same bit-exact
    device→host gather the offload tier spills through, so a restored
    engine (``ServingEngine.restore`` on a fresh engine built from the
    SAME config/params/mesh) resumes token-identically to one that was
    never killed — in exact AND prism decode modes (the prism means
    rows kz/vz/gz/zsum are part of the gathered payload).

    ``active`` holds ``(slot, RequestState, payload, n_pages)`` per
    live slot; RNG state travels inside the deepcopied RequestStates
    (the per-request numpy Generators pickle their exact position)."""
    now: float                         # engine-clock time of the cut
    next_rid: int
    active: list                       # [(slot, state, payload, n_pages)]
    queues: dict                       # priority -> [Request] (fresh)
    resume: dict                       # priority -> [RequestState]
    pending: list                      # future arrivals (heap entries)
    suspended: dict                    # rid -> RequestState
    store_entries: list                # journalled SpilledEntry objects
    results: dict                      # rid -> finished RequestState
    failed: dict                       # rid -> failure reason
    stats: EngineStats
    injector: object                   # FaultInjector mid-stream (or None)
    decodes_since_prefill: int
    drain: bool
    has_deadlines: bool


class ServingEngine:
    """Multiplexes independent requests through a fixed pool of decode
    slots backed by one ``KVCache`` (paged pool + page table by
    default; the dense rowset survives as the ``paged=False`` oracle
    and the padded-admission path)."""

    def __init__(self, cfg: ModelConfig, mesh, params,
                 config: EngineConfig | None = None, *,
                 clock=time.monotonic, **kwargs):
        if config is None:
            config = EngineConfig(**kwargs)   # legacy kwarg construction
        elif kwargs:
            raise TypeError(
                f"pass either an EngineConfig or legacy kwargs, not "
                f"both (got extra {sorted(kwargs)})")
        unsupported = {k for k in cfg.block_kinds
                       if k in ("mlstm", "slstm", "mamba", "attn_local")}
        if unsupported:
            # The admission scheme relies on the cache being addressed
            # purely by global position: right-padded prefill leaves the
            # real rows exact, and the rewind rewrite is idempotent.
            # Recurrent SSM state consumes pad tokens (and the rewind
            # would double-feed the last prompt token), and the ring
            # window cache holds the padded tail, so those blocks need a
            # state-snapshot admission path — future work.  The static
            # serve path (repro.launch.serve without --engine) still
            # covers these architectures.
            raise ValueError(
                f"ServingEngine does not support block kinds "
                f"{sorted(unsupported)} (arch {cfg.name!r}); only "
                "global-attention caches (attn/moe/shared_attn) admit "
                "correctly")
        if cfg.arch_type == "vlm" or cfg.frontend:
            # those prefill signatures require an 'embeds' input the
            # engine's token-only admission path never builds
            raise ValueError(
                f"ServingEngine serves token prompts only; arch "
                f"{cfg.name!r} (arch_type={cfg.arch_type!r}, "
                f"frontend={cfg.frontend!r}) needs embedding inputs")
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.config = config
        hp, prism = config.hp, config.prism
        n_slots = config.n_slots
        self.n_slots, self.prefill_len = n_slots, config.prefill_len
        self.prefill_mode = config.prefill_mode
        self.chunk_len = config.chunk_len
        self.token_budget = int(config.token_budget)
        self.pad_id, self._clock = config.pad_id, clock
        self._hp, self._prism = hp, prism
        self._max_cache = config.max_cache

        # cache layout + paging geometry (the paged placement depends
        # only on decode mode, not on the pool shape, so the aligned
        # base layout can seed the page-size derivation)
        base = make_layout(cfg, mesh, n_slots, config.max_cache, hp,
                           config.prefill_len)
        self._paging = (self._derive_paging(base, config)
                        if config.paged else None)
        self.layout = make_layout(cfg, mesh, n_slots, config.max_cache,
                                  hp, config.prefill_len,
                                  _paged_placement(hp, self._paging))
        self._paged = self._paging is not None
        self._prefix_on = bool(config.prefix_cache) and self._paged
        # prism pages the means state per request but keeps the aligned
        # placement — rows are whole-row allocations, prefixes unshared
        self._full_row = self._paged and hp.decode_mode == "prism"

        # the one cache object: device storage + (paged) page table /
        # prefix cache + the alloc/bind/free lifecycle
        self._kv = make_kv_cache(cfg, mesh, self.layout, n_slots, hp,
                                 paging=self._paging,
                                 prefix_cache=self._prefix_on)
        # compiled-program cache: one entry per (kind, token_budget),
        # so ticks that alternate program kinds (packed <-> decode)
        # reuse the SAME jitted callable and never retrace —
        # runtime.serve.trace_counts pins this in the tests
        self._programs: dict = {}
        self._step = self._program("decode")
        if self.prefill_mode == "packed":
            self._packed = self._program("packed", self.token_budget)
        elif self.prefill_mode == "chunked":
            self._chunk = self._program("chunk")
        else:
            self._prefill = self._program("padded_prefill")

        self._sched = FifoScheduler(
            n_slots, decode_per_prefill=config.decode_per_prefill,
            gang=config.gang)
        self.stats = EngineStats(n_slots=n_slots)
        self._pending: list = []       # heap of (arrival, rid, Request)
        self._results: dict = {}       # rid -> RequestState
        self._plans: dict = {}         # rid -> reserved AdmitPlan
        self._next_rid = 0
        self._t0 = None                # clock origin (first submit/run)
        # seeded chaos: one injector per engine, shared with the store
        # so every fault kind draws from the same replayable plan
        self._injector = (FaultInjector(config.faults)
                          if config.faults is not None else None)
        # host offload tier: spilled KV pages + prism state, keyed by
        # rid.  Tests may swap in a capacity-limited / faulty store.
        self._store = (KVStore(capacity_bytes=config.offload_bytes,
                               injector=self._injector)
                       if config.offload else None)
        self._suspended: dict = {}     # rid -> parked RequestState
        self._from_store: set = set()  # rids whose reservation restores
        self._failed: dict = {}        # rid -> reason (deadline/restarts)
        self._has_deadlines = False    # any live request with a deadline
        # the NaN/inf guard rides the hot decode paths; the padded
        # flush admission cannot re-prefill an active slot in place,
        # so quarantine is only armed for the packed/chunked engines
        self._nan_guard = self.prefill_mode != "padded"
        # degraded-mesh serving (shard_loss): the standby replica is
        # armed only when the fault is schedulable AND the cache is
        # paged (captures ride the extract_slot gather; recovery rides
        # the page-table scrub + re-prefill path).  Non-paged engines
        # never draw the shard_loss stream.
        self._replica = None
        self._lost: set = set()        # sequence shards currently dead
        self._degraded_left = 0        # grace ticks before recovery
        if (self._injector is not None and self._paged
                and config.faults.spec("shard_loss").enabled):
            self._replica = MeansReplica(
                cfg, self.layout, hp, self._paging, n_slots,
                refresh_every=config.replica_refresh)

    @staticmethod
    def _derive_paging(base, config: EngineConfig):
        """Pool geometry from the layout.  The default page size aims
        for ~16-token spans while keeping ``page_cols`` a divisor of
        both the per-shard prefill region and row capacity (whole-page
        static slices everywhere)."""
        if config.page_tokens is None:
            pc = math.gcd(math.gcd(base.n_loc0, base.cap_l),
                          max(1, 16 // base.n_seq))
            page_tokens = pc * base.n_seq
        else:
            page_tokens = config.page_tokens
        return make_paged_layout(base, page_tokens=page_tokens,
                                 n_pages=config.n_pages,
                                 n_slots=config.n_slots)

    @property
    def kv_cache(self):
        """The engine's ``KVCache`` (page table, prefix cache, device
        storage) — exposed for tests, stats, and offload tiers."""
        return self._kv

    @property
    def kv_store(self):
        """The host offload tier (None unless ``offload=True``)."""
        return self._store

    # ------------------------------------------------------------------
    # compiled-program cache
    # ------------------------------------------------------------------
    def _program(self, kind: str, token_budget: int | None = None):
        """Build-or-fetch one of the engine's compiled step programs.

        Keyed by ``(kind, token_budget)``: repeated requests return the
        SAME jitted callable, so however the engine's ticks alternate
        (packed while anything prefills, plain decode otherwise) each
        program traces at most once per engine — the regression test in
        ``tests/test_packed_step.py`` asserts the bound via the
        trace-time counters in ``repro.runtime.serve``."""
        key = (kind, token_budget)
        if key in self._programs:
            return self._programs[key]
        cfg, mesh, params, hp = self.cfg, self.mesh, self.params, self._hp
        kw = dict(batch=self.n_slots, cap=self._max_cache,
                  prefill_len=self.prefill_len, hp=hp,
                  paging=self._paging)
        if kind == "decode":
            prog, lay, _, _ = make_serve_step(cfg, mesh, params, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "decode_degraded":
            # the shard-loss variant: built lazily on the first
            # degraded tick, then cached like every other program
            prog, lay, _, _ = make_serve_step(cfg, mesh, params,
                                              degraded=True, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "packed":
            prog, lay, _, _ = make_packed_step(
                cfg, mesh, params, token_budget=token_budget, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "chunk":
            prog, lay, _ = make_chunk_prefill_step(
                cfg, mesh, params, chunk_len=self.chunk_len, **kw)
            assert lay == self.layout, (lay, self.layout)
        elif kind == "padded_prefill":
            # legacy padded admission, dense rowset only.  The captured
            # cache rows are sized straight to decode capacity (``cap``)
            # so admission is one splice per request — the old 'grow'
            # program is gone.  (make_prefill_step re-derives
            # PrismConfig.P from the layout's n_seq; only mode/cr of
            # ``prism`` matter here.)
            prog, lay_p, _, _ = make_prefill_step(
                cfg, mesh, params, self._prism, batch=self.n_slots,
                n=self.prefill_len, hp=hp, cap=self._max_cache)
            assert lay_p == self.layout, (lay_p, self.layout)
        else:
            raise ValueError(kind)
        self._programs[key] = prog
        return prog

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def submit(self, prompt, *, max_new_tokens: int, eos_id=None,
               sampling: SamplingParams = SamplingParams(),
               arrival: float | None = None, priority: int = 0,
               deadline: float | None = None) -> int:
        """Queue one request.  ``arrival`` (engine-relative seconds) may
        lie in the future — the run loop holds the request back until
        the clock passes it, which is how Poisson traces are replayed.
        ``priority`` (higher = more urgent) picks the admission class;
        with ``offload=True`` a blocked higher-priority arrival preempts
        lower-priority work into the host KV store.  ``deadline`` is an
        absolute engine-clock time (same clock as ``arrival`` — wall
        seconds, or logical steps under an injected clock): once the
        clock passes it the request is cancelled wherever it is
        (queued, prefilling, decoding, spilled, or suspended), its
        pages/store bytes are reclaimed, and the miss is counted per
        priority class."""
        prompt = tuple(int(t) for t in prompt)
        if not 1 <= len(prompt) <= self.prefill_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, {self.prefill_len}]")
        if len(prompt) + max_new_tokens > self.layout.cap:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new_tokens} exceeds "
                f"cache capacity {self.layout.cap}")
        arrival = self.now() if arrival is None else arrival
        if deadline is not None:
            if deadline <= arrival:
                raise ValueError(
                    f"deadline {deadline} <= arrival {arrival}")
            self._has_deadlines = True
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, sampling=sampling,
                      arrival=arrival, priority=priority,
                      deadline=deadline)
        # always route through the arrival-ordered pending heap so a
        # late submit with an already-past arrival cannot jump ahead of
        # earlier arrivals still waiting to be released (FIFO by
        # arrival time; rid breaks ties in submit order)
        heapq.heappush(self._pending, (req.arrival, rid, req))
        self._release_arrivals()
        return rid

    def _release_arrivals(self):
        now = self.now()
        while self._pending and self._pending[0][0] <= now:
            self._sched.submit(heapq.heappop(self._pending)[2])
        self._sched.drain = not self._pending

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest not-yet-released request —
        what an external drive loop (logical-clock benchmarks) jumps
        the clock to when the engine reports 'idle'."""
        return self._pending[0][0] if self._pending else None

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------
    def step(self) -> str:
        """Run one scheduler decision: a packed tick (chunked mode: a
        prefill chunk; padded mode: an admission flush), a decode step,
        a stall (chaos ``tick_delay``), or nothing ('idle').  Returns
        which.  In packed mode a tick with nothing prefilling falls
        through to the plain decode program — both programs live in the
        compiled-program cache, so alternating kinds never retrace."""
        kind = self._step_inner()
        if kind == "idle":
            self.stats.ticks_idle += 1
        if self._injector is not None:
            self.stats.faults_injected = self._injector.total_injected
            self.stats.faults_by_kind = dict(self._injector.injected)
        if self._store is not None:
            self.stats.store_get_retries = self._store.get_retries
        # standby-replica piggyback: after a healthy tick, capture any
        # newly decoding slot (plus one bounded staleness refresh).
        # NEVER while degraded — a capture would read the lost shard.
        if (self._replica is not None and not self._lost
                and kind in ("decode", "packed", "prefill")):
            self._replica.tick(self._kv, self._sched.decoding(),
                               self.stats.decode_steps
                               + self.stats.packed_ticks)
        return kind

    def _step_inner(self) -> str:
        sch = self._sched
        self._release_arrivals()
        if self.stats.t_start is None:
            self.stats.t_start = self.now()
        if self._has_deadlines:
            self._expire()
        if (self._injector is not None and sch.has_work
                and self._injector.fire("tick_delay")):
            return "stalled"           # the whole tick does nothing
        if (self._replica is not None and sch.has_work
                and self._injector.fire("shard_loss")):
            spec = self._injector.plan.spec("shard_loss")
            shard = (spec.shard if spec.shard is not None
                     else self._injector.pick("shard_loss",
                                              self.layout.n_seq))
            self._lose_shard(shard % self.layout.n_seq)
        if self._lost:
            return self._degraded_tick()

        if self.prefill_mode == "padded":
            if sch.want_prefill():
                return self._padded_flush()
        elif self.prefill_mode == "chunked":
            self._admit_or_preempt()       # host-side: slots + pages
            if sch.want_chunk():
                return self._chunk_step()
        else:                              # packed: one program per tick
            self._admit_or_preempt()       # host-side: slots + pages
            if any(st.prefilling for st in sch.active.values()):
                return self._packed_tick()

        decoding = sch.decoding()
        if decoding:
            self._maybe_poison()
            tok = np.zeros(self.n_slots, np.int32)
            pos = np.full(self.n_slots, -1, np.int32)
            for st in decoding:
                tok[st.slot] = st.next_token
                pos[st.slot] = st.pos
            t0 = self.now()
            logits, self._kv.storage = self._step(
                self.params, self._kv.storage, jnp.asarray(tok),
                jnp.asarray(pos), *self._maps())
            rows = np.asarray(jax.device_get(logits))
            now = self.now()
            self.stats.step_latency.append(now - t0)
            self.stats.occupancy.append(len(sch.active) / self.n_slots)
            self.stats.decode_steps += 1
            # ONE fused non-finite reduction over the tick's logits —
            # the quarantine trigger costs a single host-side pass
            bad = (~np.isfinite(rows).all(axis=-1)
                   if self._nan_guard else None)
            for st in decoding:
                if bad is not None and bad[st.slot]:
                    self._quarantine(st)
                else:
                    self._advance_decode(st, rows[st.slot], now)
            sch.note_decode()
            self.stats.t_end = self.now()
            return "decode"
        return "idle"

    # ------------------------------------------------------------------
    # admission (page-aware) + per-tick device maps
    # ------------------------------------------------------------------
    def _maps(self) -> tuple:
        """The paged step programs take the per-slot (page_map,
        state_map) device arrays each tick; dense programs take
        nothing."""
        if not self._paged:
            return ()
        return (jnp.asarray(self._kv.page_map(self.n_slots)),
                jnp.asarray(self._kv.state_map(self.n_slots)))

    def _admit_gate(self, req) -> bool:
        """Page-aware admission check, consulted by the scheduler on
        the FIFO head: plan the request's page needs (prefix lookup
        included), reclaim LRU prefix entries if the free list is
        short, and RESERVE the pages before the scheduler pops the
        request — so several admissions in one engine loop can never
        double-count the free list."""
        kv = self._kv
        plan = kv.plan(req.prompt, req.max_new_tokens,
                       use_prefix=self._prefix_on,
                       full_row=self._full_row)
        if not kv.can_admit(plan, reclaim=False):
            if kv.prefix is not None:
                kv.prefix.reclaim(plan.fresh_pages)
                # reclaim may have dropped the very entry the plan
                # shares — re-plan against the surviving entries
                plan = kv.plan(req.prompt, req.max_new_tokens,
                               use_prefix=self._prefix_on,
                               full_row=self._full_row)
            if not kv.can_admit(plan, reclaim=False):
                self.stats.out_of_pages += 1
                return False
        if not kv.reserve(req.rid, plan):
            self.stats.out_of_pages += 1
            return False
        self._plans[req.rid] = plan
        return True

    def _restore_gate(self, st: RequestState) -> bool:
        """Admission check for a preempted request coming back from the
        host store: the plan's page count (and covered-token count)
        comes from the spilled entry instead of the prefix cache.  When
        the store LOST the entry (host-memory pressure / fault
        injection) the recovery is per-request and clean: reset the
        state for a full re-prefill and fall through to the ordinary
        fresh-admission gate — greedy/seeded sampling makes the rerun
        deterministic, and no other slot is touched."""
        kv, rid = self._kv, st.req.rid
        plan = kv.plan_restore(rid, self._store,
                               retries=self.config.restore_retries,
                               backoff_s=self.config.restore_backoff_s)
        if plan is None:
            self.stats.restore_misses += 1
            if st.restarts >= self.config.max_restarts:
                # the restart budget is spent: fail the head candidate
                # here (it holds no pages, no slot, no store entry) so
                # it cannot block the admission queue forever
                self._sched.cancel(rid)
                self._store.drop(rid)
                self._failed[rid] = "max_restarts"
                self.stats.failed_requests += 1
                return False
            self._note_restart(st)
            return self._admit_gate(st.req)
        if not kv.can_admit(plan, reclaim=False):
            if kv.prefix is not None:
                kv.prefix.reclaim(plan.fresh_pages)
            if not kv.can_admit(plan, reclaim=False):
                self.stats.out_of_pages += 1
                return False
        if not kv.reserve(rid, plan):
            self.stats.out_of_pages += 1
            return False
        self._plans[rid] = plan
        self._from_store.add(rid)
        return True

    def _gate(self, cand) -> bool:
        """Dispatch the page-aware admission gate on the candidate
        kind: fresh Request vs RequestState resuming from the store."""
        if isinstance(cand, RequestState):
            return self._restore_gate(cand)
        return self._admit_gate(cand)

    def _admit(self) -> list:
        """Assign free slots to queued requests; in paged mode each
        admission binds its reserved pages to the slot, then either a
        prefix hit fast-forwards the prompt past the tokens its shared
        pages already hold, or — for a resume — the spilled content is
        injected back into the freshly bound pages."""
        states = self._sched.admit(
            self.now(), gate=self._gate if self._paged else None)
        for st in states:
            if not self._paged:
                continue
            rid = st.req.rid
            self._kv.bind(rid, st.slot)
            plan = self._plans.pop(rid)
            if rid in self._from_store:
                self._from_store.discard(rid)
                if self._kv.restore(
                        rid, st.slot, self._store,
                        retries=self.config.restore_retries,
                        backoff_s=self.config.restore_backoff_s):
                    self.stats.restore_hits += 1
                else:
                    # entry evicted between plan and bind: the bound
                    # pages are large enough for a full re-prefill
                    self.stats.restore_misses += 1
                    if st.restarts >= self.config.max_restarts:
                        self._fail_active(st, "max_restarts")
                        continue
                    self._note_restart(st)
            elif plan.covered:
                st.nprefilled = plan.covered
                self.stats.prefix_hits += 1
                self.stats.prefix_tokens_saved += plan.covered
        return states

    def _admit_or_preempt(self) -> None:
        """The tick loop's admission move: admit what fits, then — with
        the offload tier on — spill strictly-lower-priority active work
        whenever the head admission candidate is still blocked (no free
        slot, or ``out_of_pages``).  Each spill is one device→host
        gather; the victim's RequestState parks on the scheduler's
        resume queue and restores through the normal admission path
        once pressure clears.  Equal-priority arrivals never preempt —
        the pool drains by itself and swapping would only thrash."""
        sch = self._sched
        if (self._injector is not None and sch.queued
                and self._injector.fire("admission_stall")):
            return                     # control plane stuck this tick
        if sch.want_admit():
            self._admit()
        if self._store is None:
            return
        while True:
            cand = sch.peek_admit()
            if cand is None:
                return
            prio = (cand.req.priority if isinstance(cand, RequestState)
                    else cand.priority)
            victim = sch.pick_victim(prio, now=self.now())
            if victim is None:
                return
            self._spill(victim)
            self._admit()

    def _spill(self, st: RequestState, *, requeue: bool = True) -> None:
        """Preempt an active request: gather its pages (+ prism state
        row) into the host store, free the device footprint, and either
        park it for automatic resume or hand it to the caller
        (suspend)."""
        n = self._kv.spill(st.req.rid, st.slot, self._store,
                           tokens=st.nprefilled)
        self.stats.preemptions += 1
        self.stats.spilled_pages += n
        self._drop_replica(st.slot)
        if requeue:
            self._sched.preempt(st)
        else:
            self._sched.remove(st)

    def _find_active(self, rid: int) -> RequestState | None:
        for st in self._sched.active.values():
            if st.req.rid == rid:
                return st
        return None

    # -- fault injection + quarantine ----------------------------------
    def _maybe_poison(self) -> None:
        """Chaos ``page_poison``: NaN-fill the first page of one
        decoding slot whose page is PRIVATE (refcount 1 — shared prefix
        pages are other requests' reads; corrupting one would break the
        neighbour-isolation guarantee the quarantine test pins).  Page 0
        always holds attended positions, so in exact decode mode the
        poison reaches the slot's next logits row and the isfinite
        guard fires the same tick.  Prism decode reads remote content
        through the precomputed means state, where raw-page poison can
        go undetected and leak through the free list — injection is
        exact-mode only.

        Injection happens only before PURE-DECODE ticks, where one
        poisoned page NaNs exactly its own slot's logits row (the
        quarantine test pins this isolation).  The token-packed program
        is excluded: its intra-tick pass masks cross-request columns
        with an additive ``NEG_INF`` bias and folds ``0 * NaN`` in the
        stat combine, so one poisoned slot's second-layer K/V
        projection would NaN every decode row in the tick — detection
        still fires and recovery stays token-identical, but the blast
        radius (spurious neighbour quarantines) would be wrong.  The
        packed path keeps its isfinite guard armed purely defensively;
        same-tick detection on the decode path means a scrub always
        lands before any packed tick can gather the poisoned page."""
        if (self._injector is None or not self._paged
                or self._hp.decode_mode != "exact"):
            return
        kv = self._kv
        cands = [st for st in self._sched.decoding()
                 if kv.slot_pages.get(st.slot)
                 and kv.table.refs[kv.slot_pages[st.slot][0]] == 1]
        if not cands or not self._injector.fire("page_poison"):
            return
        st = cands[self._injector.pick("page_poison", len(cands))]
        kv.poison_page(kv.slot_pages[st.slot][0])

    def _note_restart(self, st: RequestState) -> None:
        """The one re-prefill entry point: every recovery path (lost
        restore, quarantine) goes through here so the aggregate restart
        counter can never drift from the per-request ones."""
        st.reset_for_refill()
        self.stats.restarts += 1

    def _fail_active(self, st: RequestState, reason: str) -> None:
        """Fail-hard an ACTIVE request: scrub its private pages (NaN
        content must never rejoin the free list — masked attention
        still folds ``0 * NaN``), release pages + slot, and record the
        failure.  The request never reaches ``results()``."""
        if self._paged:
            self._kv.scrub_slot(st.slot)
            self._kv.free(st.slot, None)   # never register the prompt
        else:
            self._kv.reset_row(st.slot)
        self._drop_replica(st.slot)
        self._sched.remove(st)
        self._failed[st.req.rid] = reason
        self.stats.failed_requests += 1

    def _quarantine(self, st: RequestState) -> None:
        """Non-finite logits on a decode row: quarantine exactly this
        slot.  Recovery is the existing ``reset_for_refill`` re-prefill
        path — scrub the slot's pages and state row in place, then
        replay the prompt into the SAME bound pages; per-request seeded
        sampling makes the regenerated tokens identical.  Bounded by
        ``max_restarts``: a slot that keeps producing NaNs fails hard
        instead of burning ticks forever."""
        self.stats.quarantined += 1
        if st.restarts >= self.config.max_restarts:
            self._fail_active(st, "max_restarts")
            return
        if self._paged:
            try:
                # fork any COW-shared prefix pages private first — the
                # re-prefill rewrites position 0 onward, and shared
                # pages must never see a write
                self._kv.ensure_writable(st.slot, 0,
                                         len(st.req.prompt) - 1)
            except RuntimeError:
                self._fail_active(st, "quarantine_out_of_pages")
                return
            self._kv.scrub_slot(st.slot)
        else:
            self._kv.reset_row(st.slot)
        self._note_restart(st)
        self._drop_replica(st.slot)

    # -- degraded-mesh serving (shard loss) ----------------------------
    def _drop_replica(self, slot: int) -> None:
        if self._replica is not None:
            self._replica.drop(slot)

    def _lose_shard(self, shard: int) -> None:
        """A ``shard_loss`` fault fired: one sequence shard's KV is now
        unreadable.  Mark the degraded window open (the next
        ``_degraded_left`` ticks serve through the standby replicas)
        and empty the prefix cache — its shared pages hold content on
        the dead shard, so every future hit would splice garbage."""
        if shard in self._lost:
            return
        self._lost.add(shard)
        self.stats.shard_lost += 1
        self._degraded_left = self.config.degraded_grace
        if self._kv.prefix is not None:
            self._kv.prefix.clear()

    def _degraded_tick(self) -> str:
        """One serving tick with >= 1 sequence shard dead.  In-flight
        decode requests keep emitting finite tokens through the
        degraded program: the lost shard's exact columns are masked out
        of the stat combine and its standby Segment-Means columns are
        substituted through the log-g bias path (PRISM-bounded quality
        loss instead of failure).  No admissions, no prefill, no
        replica captures (a capture would gather the dead shard), and
        crucially NO evictions — a request that looks finished is HELD
        in its slot so its degraded tail tokens never reach
        ``results()``; recovery re-prefills it and regenerates every
        token exactly.  When the grace window closes (or nothing is
        decoding) the tick recovers instead."""
        sch = self._sched
        decoding = [st for st in sch.decoding() if not st.finished()]
        if self._degraded_left <= 0 or not decoding:
            return self._recover_from_loss()
        self.stats.degraded_ticks += 1
        self._degraded_left -= 1
        tok = np.zeros(self.n_slots, np.int32)
        pos = np.full(self.n_slots, -1, np.int32)
        for st in decoding:
            tok[st.slot] = st.next_token
            pos[st.slot] = st.pos
        lost = jnp.asarray(self._replica.lost_mask(self._lost))
        args = (jnp.asarray(tok), jnp.asarray(pos), *self._maps(), lost)
        if self._hp.decode_mode == "exact":
            args = args + (self._replica.assemble(),)
        step = self._program("decode_degraded")
        t0 = self.now()
        logits, self._kv.storage = step(self.params, self._kv.storage,
                                        *args)
        rows = np.asarray(jax.device_get(logits))
        now = self.now()
        self.stats.step_latency.append(now - t0)
        self.stats.occupancy.append(len(sch.active) / self.n_slots)
        bad = ~np.isfinite(rows).all(axis=-1)
        for st in decoding:
            if bad[st.slot]:
                continue    # don't quarantine: recovery resets it anyway
            self._advance_degraded(st, rows[st.slot], now)
        sch.note_decode()
        self.stats.t_end = self.now()
        return "degraded"

    def _advance_degraded(self, st: RequestState, logits_row,
                          now) -> None:
        """Advance one decode slot on a degraded tick: sample and
        stream the approximate token, but never finish/evict — the slot
        is held until ``_recover_from_loss`` resets it, which is what
        keeps the final ``results()`` oracle-identical."""
        t = sample_token(logits_row, st.req.sampling, st.rng)
        st.generated.append(t)
        self.stats.generated_tokens += 1
        if st.ttft is None:
            st.ttft = now - st.req.arrival
            self.stats.ttft.append(st.ttft)
        st.pos += 1
        st.next_token = t

    def _recover_from_loss(self) -> str:
        """Close the degraded window: rebuild EXACT KV for every active
        request and return to exact serving.  Device-side content is
        gone on the lost shard, so each slot goes through the
        deterministic ``reset_for_refill`` re-prefill (scrub + replay
        the prompt into the same bound pages; seeded sampling makes the
        rerun token-identical to the uninterrupted oracle).  Spilled /
        suspended entries live HOST-side in the offload store and
        survive shard loss untouched — they restore through the normal
        admission path after recovery.  Requests admitted after this
        tick never see the degraded program."""
        sch = self._sched
        for _slot, st in sorted(sch.active.items()):
            if st.restarts >= self.config.max_restarts:
                self._fail_active(st, "max_restarts")
                continue
            try:
                # fork COW-shared prefix pages private before the
                # re-prefill rewrites position 0 onward
                self._kv.ensure_writable(st.slot, 0,
                                         len(st.req.prompt) - 1)
            except RuntimeError:
                self._fail_active(st, "degraded_out_of_pages")
                continue
            self._kv.scrub_slot(st.slot)
            self._note_restart(st)
        if self._replica is not None:
            self._replica.drop_all()
        self._lost.clear()
        self._degraded_left = 0
        return "recovered"

    # -- deadline expiry -----------------------------------------------
    def _miss(self, req, *, st: RequestState | None = None,
              now: float | None = None) -> None:
        self.stats.deadline_miss += 1
        cls = self.stats.deadline_miss_by_class
        cls[req.priority] = cls.get(req.priority, 0) + 1
        self._failed[req.rid] = "deadline"
        if st is not None and st.t_finish is None and now is not None:
            st.t_finish = now

    def _expire(self) -> None:
        """Cancel every request whose deadline has passed, wherever it
        sits in the lifecycle: future arrival, fresh queue, resume
        queue (spilled), suspended (spilled), or active (prefilling or
        decoding).  Each path reclaims exactly the resources that state
        holds — heap entry, queue position, store bytes, or bound
        pages + state row + slot — so a deadline storm leaves the
        engine leak-free (the chaos audits pin this)."""
        now = self.now()
        dead = lambda req: req.deadline is not None and now >= req.deadline
        # future arrivals (heap)
        expired = [e for e in self._pending if dead(e[2])]
        if expired:
            self._pending = [e for e in self._pending if not dead(e[2])]
            heapq.heapify(self._pending)
            for _, _, req in expired:
                self._miss(req)
        sch = self._sched
        # fresh queues + resume queues (spilled entries also free store
        # bytes)
        for q in sch.queues.values():
            for req in [r for r in q if dead(r)]:
                q.remove(req)
                self._miss(req)
        for q in sch.resume.values():
            for st in [s for s in q if dead(s.req)]:
                q.remove(st)
                if self._store is not None:
                    self._store.drop(st.req.rid)
                self._miss(st.req, st=st, now=now)
        # suspended sessions (store entry, no slot)
        for rid in [r for r, s in self._suspended.items()
                    if dead(s.req)]:
            st = self._suspended.pop(rid)
            self._store.drop(rid)
            self._miss(st.req, st=st, now=now)
        # active slots: free pages + state row + slot.  The prompt is
        # never registered in the prefix cache — a cancelled request
        # may hold a partially-prefilled page set.
        for st in [s for s in list(sch.active.values()) if dead(s.req)]:
            if self._paged:
                self._kv.free(st.slot, None)
            else:
                self._kv.reset_row(st.slot)
            self._drop_replica(st.slot)
            sch.remove(st)
            self._miss(st.req, st=st, now=now)
        self._has_deadlines = any(
            r.deadline is not None
            for r in self._live_requests())

    def _live_requests(self):
        """Every not-yet-finished Request the engine still tracks."""
        for _, _, req in self._pending:
            yield req
        for q in self._sched.queues.values():
            yield from q
        for q in self._sched.resume.values():
            for st in q:
                yield st.req
        for st in self._sched.active.values():
            yield st.req
        for st in self._suspended.values():
            yield st.req

    # -- public offload controls ---------------------------------------
    def preempt(self, rid: int) -> bool:
        """Force-preempt an active request into the host store; it
        requeues for automatic restore (fair resume ordering).  The
        tick loop preempts on priority pressure by itself — this hook
        exists for tests, draining, and external policies."""
        st = self._find_active(rid)
        if st is None or self._store is None:
            return False
        self._spill(st, requeue=True)
        return True

    def suspend(self, rid: int) -> bool:
        """Evict an idle multi-turn session to the host tier.  The
        request keeps its KV in the store but does NOT requeue — it
        consumes no slot, no pages, and no scheduler attention until
        ``resume(rid)``.  ``run()`` does not wait for suspended
        requests."""
        st = self._find_active(rid)
        if st is None or self._store is None:
            return False
        self._spill(st, requeue=False)
        self._suspended[rid] = st
        return True

    def resume(self, rid: int) -> bool:
        """Requeue a suspended session; its cache restores through the
        normal admission path on the next tick with free capacity."""
        st = self._suspended.pop(rid, None)
        if st is None:
            return False
        self._sched.push_resume(st)
        return True

    def cancel(self, rid: int) -> bool:
        """Drop a request wherever it sits in the lifecycle: pending
        (future arrival), queued, parked for resume, suspended, or
        ACTIVE (prefilling or decoding mid-flight).  Each path reclaims
        exactly what that state holds — heap entry, queue position,
        store bytes, or bound pages + state row + slot (the same
        zero-leak release the deadline sweep uses; the prompt is never
        prefix-registered, since a cancelled request may hold a
        partially-prefilled page set).  A cancelled request lands in
        ``failed()`` under reason ``'cancelled'`` and never reaches
        ``results()``.  Callers driving the engine through the
        streaming front-end must cancel via ``StreamingEngine.cancel``,
        which drains the in-flight tick pipeline first."""
        for i, (_, r, req) in enumerate(self._pending):
            if r == rid:
                self._pending.pop(i)
                heapq.heapify(self._pending)
                self.stats.cancelled += 1
                return True
        if self._sched.cancel(rid) is not None:
            if self._store is not None:
                self._store.drop(rid)
            self.stats.cancelled += 1
            return True
        if self._suspended.pop(rid, None) is not None:
            self._store.drop(rid)
            self.stats.cancelled += 1
            return True
        st = self._find_active(rid)
        if st is not None:
            if self._paged:
                self._kv.free(st.slot, None)
            else:
                self._kv.reset_row(st.slot)
            self._drop_replica(st.slot)
            self._sched.remove(st)
            st.t_finish = self.now()
            self._failed[rid] = "cancelled"
            self.stats.cancelled += 1
            return True
        return False

    # -- crash-consistent snapshot / restore ---------------------------
    def snapshot(self) -> EngineSnapshot:
        """Journal the engine's complete serving state into one
        host-side object: every live slot's pages + prism state row
        (the PR-7 bit-exact gather, non-destructive), the scheduler
        queues, pending arrivals, suspended sessions, the offload
        store's entries, per-request RNG states (inside the deepcopied
        RequestStates), stats, and the fault injector's stream
        position.  Must be called between steps (no reservation in
        flight — always true outside ``step()``)."""
        if not self._paged:
            raise ValueError(
                "snapshot requires the paged cache (paged=True): the "
                "journal rides the page gather path")
        assert not self._plans and not self._from_store, (
            "snapshot mid-admission: call between engine steps")
        if self._lost:
            raise ValueError(
                "snapshot during a degraded window (shard lost): the "
                "page gather would read the dead shard — recover first")
        active = []
        for slot, st in sorted(self._sched.active.items()):
            active.append((slot, copy.deepcopy(st),
                           self._kv.extract_slot(slot),
                           len(self._kv.slot_pages[slot])))
        return EngineSnapshot(
            now=self.now(),
            next_rid=self._next_rid,
            active=active,
            queues={p: list(q) for p, q in self._sched.queues.items()
                    if q},
            resume={p: copy.deepcopy(q)
                    for p, q in self._sched.resume.items() if q},
            pending=copy.deepcopy(self._pending),
            suspended=copy.deepcopy(self._suspended),
            store_entries=(copy.deepcopy(self._store.entries())
                           if self._store is not None else []),
            results=copy.deepcopy(self._results),
            failed=dict(self._failed),
            stats=copy.deepcopy(self.stats),
            injector=copy.deepcopy(self._injector),
            decodes_since_prefill=self._sched._decodes_since_prefill,
            drain=self._sched.drain,
            has_deadlines=self._has_deadlines)

    def restore(self, snap: EngineSnapshot) -> None:
        """Rebuild the journalled serving state into THIS engine —
        which must be fresh (no requests yet) and built from the same
        config over the same params/mesh.  Each journalled slot
        re-reserves its page count through the normal two-phase
        admission and injects its payload; page ids may differ from the
        killed engine's, which the per-tick maps make invisible.  The
        prefix cache intentionally starts cold (it is a cache — losing
        it costs recompute, never tokens).  The snapshot object is not
        consumed: the same journal can restore any number of fresh
        engines."""
        if not self._paged:
            raise ValueError("restore requires the paged cache")
        if self._next_rid or self._sched.active or self._sched.queued:
            raise ValueError("restore target must be a fresh engine")
        snap = copy.deepcopy(snap)     # keep the journal re-restorable
        sch = self._sched
        sch.queues = {p: deque(q) for p, q in snap.queues.items()}
        sch.resume = snap.resume
        sch.drain = snap.drain
        sch._decodes_since_prefill = snap.decodes_since_prefill
        self._pending = snap.pending
        heapq.heapify(self._pending)
        self._suspended = snap.suspended
        if self._store is not None:
            self._store.adopt(snap.store_entries)
        for slot, st, payload, n_pages in snap.active:
            key = ("__restore__", slot)
            if not self._kv.reserve(key, AdmitPlan(total_pages=n_pages,
                                                   fresh_pages=n_pages)):
                raise RuntimeError(
                    f"restore out of pages binding slot {slot}")
            self._kv.bind(key, slot)
            st.slot = slot
            self._kv.inject_slot(slot, payload)
            sch.active[slot] = st
            sch.free_slots.remove(slot)
        self._results = snap.results
        self._failed = snap.failed
        self.stats = snap.stats
        self._next_rid = snap.next_rid
        self._has_deadlines = snap.has_deadlines
        if snap.injector is not None:
            self._injector = snap.injector
            if self._store is not None:
                self._store._injector = snap.injector
        # clock continuity: the restored engine's now() resumes at the
        # snapshot cut, so arrivals/deadlines keep their meaning
        self._t0 = self._clock() - snap.now

    def failed(self) -> dict:
        """{rid: reason} for requests the engine gave up on (deadline
        miss, max_restarts exceeded) — disjoint from ``results()``."""
        return dict(self._failed)

    def _advance_decode(self, st, logits_row, now):
        """Sample one token for a decode-phase request and advance /
        evict it — shared by the decode step and the packed tick."""
        t = sample_token(logits_row, st.req.sampling, st.rng)
        self._advance_token(st, t, now)

    def _advance_token(self, st, t: int, now):
        """Advance a decode-phase request by one ALREADY-SAMPLED token
        (append, TTFT, position, finish/evict).  The sync tick loop
        reaches it through ``_advance_decode`` (host sampling); the
        streaming engine calls it directly with the device-argmaxed
        token carried home in the tick's ``ResultTokens`` — identical
        state transitions either way, which is what keeps streamed
        output token-identical to the synchronous engine."""
        st.generated.append(t)
        self.stats.generated_tokens += 1
        if st.ttft is None:
            st.ttft = now - st.req.arrival
            self.stats.ttft.append(st.ttft)
        st.pos += 1
        st.next_token = t
        if st.finished():
            if self._paged:
                # release the request's pages (prefix-registered full
                # prompt pages survive under their cache entries)
                self._kv.free(st.slot, st.req.prompt
                              if self._prefix_on else None)
            self._drop_replica(st.slot)
            self._sched.evict(st, now)
            self._results[st.req.rid] = st
            self.stats.completed += 1

    def _packed_tick(self) -> str:
        """ONE compiled program for the whole engine tick: every live
        decode token plus prompt-chunk tokens from every mid-prefill
        request, flattened into a (token_budget,) ragged batch (dead
        tail entries pass slot = -1).  Decode rows are sampled from the
        returned logits; prefill rows only advance their request's
        offset (the rewind then re-feeds the last prompt token, exactly
        as in chunked mode, so output stays token-identical)."""
        sch = self._sched
        decode, prefill = sch.plan_tick(self.token_budget)
        tb = self.token_budget
        tok = np.zeros(tb, np.int32)
        slot = np.full(tb, -1, np.int32)
        pos = np.full(tb, -1, np.int32)
        off = np.full(tb, -1, np.int32)
        pre = np.zeros(tb, np.int32)
        i = 0
        dec_rows = []
        for st in decode:
            tok[i], slot[i] = st.next_token, st.slot
            pos[i] = off[i] = st.pos
            dec_rows.append((i, st))
            i += 1
        n_prefill = 0
        for st, take in prefill:
            o = st.nprefilled
            tok[i:i + take] = st.req.prompt[o:o + take]
            slot[i:i + take] = st.slot
            pos[i:i + take] = np.arange(o, o + take)
            off[i:i + take] = o
            pre[i:i + take] = 1
            i += take
            n_prefill += take

        t0 = self.now()
        logits, self._kv.storage = self._packed(
            self.params, self._kv.storage, jnp.asarray(tok),
            jnp.asarray(slot), jnp.asarray(pos), jnp.asarray(off),
            jnp.asarray(pre), *self._maps())
        rows = np.asarray(jax.device_get(logits))
        now = self.now()
        self.stats.step_latency.append(now - t0)
        self.stats.occupancy.append(len(sch.active) / self.n_slots)
        self.stats.packed_ticks += 1
        self.stats.packed_decode_tokens += len(dec_rows)
        self.stats.packed_prefill_tokens += n_prefill
        self.stats.prefill_tokens += n_prefill
        # ONE fused non-finite reduction over the tick's logits; only
        # decode rows sample, so only they can quarantine
        bad = (~np.isfinite(rows).all(axis=-1)
               if self._nan_guard else None)
        for j, st in dec_rows:
            if bad is not None and bad[j]:
                self._quarantine(st)
            else:
                self._advance_decode(st, rows[j], now)
        for st, take in prefill:
            st.nprefilled += take
            if not st.prefilling:
                st.begin_decode()          # rewind: re-feed last token
        self.stats.t_end = self.now()
        return "packed"

    def _chunk_step(self) -> str:
        """Advance EVERY mid-prefill request by one chunk (each at its
        own offset) in a single compiled call.  The empty-states guard
        keeps the no-mid-prefill-no-launch invariant local (the
        scheduler's ``want_chunk`` enforces it on the step() path; a
        direct caller gets the same no-op), and the real-vs-padded
        chunk-token split is tracked so ``EngineStats.summary`` can
        report how much of each launched ``(n_slots, chunk_len)``
        program was live work — the waste the FLOP model exposed and
        packed mode eliminates."""
        sch = self._sched
        states = sch.prefilling()
        if not states:                     # nothing mid-prefill: no-op
            return "idle"
        c = self.chunk_len
        tokens = np.full((self.n_slots, c), self.pad_id, np.int32)
        off = np.full(self.n_slots, -1, np.int32)
        nreal = np.zeros(self.n_slots, np.int32)
        for st in states:
            take = min(c, len(st.req.prompt) - st.nprefilled)
            tokens[st.slot, :take] = st.req.prompt[
                st.nprefilled:st.nprefilled + take]
            off[st.slot] = st.nprefilled
            nreal[st.slot] = take
        self._kv.storage = self._chunk(self.params, self._kv.storage,
                                       jnp.asarray(tokens), jnp.asarray(off),
                                       jnp.asarray(nreal), *self._maps())
        for st in states:
            st.nprefilled += int(nreal[st.slot])
            if not st.prefilling:
                st.begin_decode()          # rewind: re-feed last token
        sch.note_chunk()
        real = int(nreal.sum())
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += real
        self.stats.chunk_tokens_real += real
        self.stats.chunk_tokens_padded += self.n_slots * c - real
        self.stats.t_end = self.now()
        return "prefill"

    def _padded_flush(self) -> str:
        """Legacy admission: right-pad every admitted prompt to
        ``prefill_len``, one monolithic prefill (its cache rows come out
        sized to decode capacity — no separate grow step), splice each
        row into its slot, start decoding at the rewind position."""
        sch = self._sched
        batch = np.full((self.n_slots, self.prefill_len), self.pad_id,
                        np.int32)
        states = sch.admit(self.now())
        for i, st in enumerate(states):
            batch[i, :len(st.req.prompt)] = st.req.prompt
        _, fresh = self._prefill(self.params, {"tokens":
                                               jnp.asarray(batch)})
        for i, st in enumerate(states):
            self._kv.insert_row(fresh, i, st.slot)
            st.begin_decode()
            self.stats.prefill_tokens += len(st.req.prompt)
        self.stats.prefills += 1
        self.stats.t_end = self.now()
        return "prefill"

    # ------------------------------------------------------------------
    # drive to completion
    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Step until every submitted request (including future
        arrivals) has finished.  Returns {rid: [generated token ids]}."""
        while True:
            kind = self.step()
            if kind != "idle":
                continue
            if self._pending:
                # nothing runnable until the next arrival — wait it
                # out.  An injected clock that doesn't tick with wall
                # time (e.g. a logical StepClock) is fast-forwarded to
                # the arrival instead, so run() terminates under both.
                before = self.now()
                dt = self.next_arrival() - before
                if dt > 0:
                    time.sleep(min(dt, 0.05))
                    if self.now() <= before:
                        self._t0 -= dt
                continue
            if not self._sched.has_work:
                break
        return self.results()

    def results(self) -> dict:
        return {rid: list(st.generated)
                for rid, st in sorted(self._results.items())}

    def request_stats(self) -> dict:
        return {rid: {"ttft_s": st.ttft,
                      "latency_s": (st.t_finish - st.req.arrival
                                    if st.t_finish is not None else None),
                      "tokens": len(st.generated)}
                for rid, st in sorted(self._results.items())}

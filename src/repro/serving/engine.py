"""Continuous-batching serving engine over the sequence-sharded runtime.

Request lifecycle (docs/serving.md has the full tour)::

    submit ──> [FIFO queue] ──> admit into a free slot (host-side)
    ──> CHUNKED PREFILL: the prompt lands chunk_len tokens at a time,
    written straight into the slot's decode-cache row at its true
    offsets, interleaved with decode steps (at most one chunk per
    decode_per_prefill decode steps while streams are decoding) ──>
    rewind to pos = len(prompt) - 1 ──> per-slot decode (pos vector;
    idle/prefilling rows carry pos = -1) ──> host-side sampling ──>
    evict on EOS / max-tokens ──> slot freed, mid-flight.

The engine owns exactly two compiled programs, each traced once:

  * ``chunk``    — batch = n_slots, up to chunk_len prompt tokens per
    row at per-row runtime offsets (rows not prefilling pass
    offset = -1).  EVERY mid-prefill request advances in the same
    call, so admission cost amortises over bursts and a long prompt
    is spread over many cheap steps instead of one monolithic flush —
    in-flight decodes keep their bounded share of the engine
    (chunk-vs-decode interleave), and a short prompt pays
    ceil(len/chunk_len) chunks instead of a full pad-to-prefill_len
    forward.
  * ``step``     — batch = n_slots single-token decode with a (B,) pos
    vector: every request decodes at its own depth.

The admission rewind: the chunk program returns no logits; when the
last chunk lands, the slot starts decoding at ``pos = len(prompt) - 1``,
re-feeding the last prompt token.  That first decode step rewrites the
token's K/V row in place (an idempotent rewrite — the computation is
identical to the chunk's) and yields exactly the teacher-forced
next-token logits.  TTFT is measured to the first token sampled from
those logits.  Chunk attention is exact (cross-shard stat combine), so
engine output is token-identical to sequential serving in every mode.

In ``prism`` decode mode the chunk program also accumulates the
Segment-Means state (kz/vz + per-request counts gz + running sums
zsum) over REAL prompt columns only — short prompts no longer fold pad
columns into the remote-means approximation, which the padded flush
admission used to do (the old wart, kept reproducible via
``prefill_mode='padded'``).

``prefill_mode='padded'`` retains the legacy three-program admission
(right-pad to ``prefill_len``, one monolithic flush, ``grow_cache`` +
``insert_cache_row`` into the slot) as the benchmark baseline and as a
fallback; docs/serving.md quantifies the difference.
"""
from __future__ import annotations

import functools
import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..core.protocol import PrismConfig
from ..models.config import ModelConfig
from ..runtime.serve import (ServeHParams, cache_specs, grow_cache,
                             init_cache, insert_cache_row,
                             make_chunk_prefill_step, make_prefill_step,
                             make_serve_step)
from .sampling import SamplingParams, sample_token
from .scheduler import EngineStats, FifoScheduler, Request


class ServingEngine:
    """Multiplexes independent requests through a fixed pool of decode
    slots backed by one batched, sequence-sharded KV cache."""

    def __init__(self, cfg: ModelConfig, mesh, params, *,
                 n_slots: int, prefill_len: int, max_cache: int,
                 hp: ServeHParams = ServeHParams(),
                 prism: PrismConfig | None = None,
                 decode_per_prefill: int = 4, gang: bool = False,
                 chunk_len: int = 64, prefill_mode: str = "chunked",
                 pad_id: int = 0, clock=time.monotonic):
        if prefill_mode not in ("chunked", "padded"):
            raise ValueError(f"prefill_mode {prefill_mode!r} not in "
                             "('chunked', 'padded')")
        if prism is None:
            prism = PrismConfig(
                P=1, cr=hp.means_cr,
                mode="prism" if hp.decode_mode == "prism" else "voltage")
        unsupported = {k for k in cfg.block_kinds
                       if k in ("mlstm", "slstm", "mamba", "attn_local")}
        if unsupported:
            # The admission scheme relies on the cache being addressed
            # purely by global position: right-padded prefill leaves the
            # real rows exact, and the rewind rewrite is idempotent.
            # Recurrent SSM state consumes pad tokens (and the rewind
            # would double-feed the last prompt token), and the ring
            # window cache holds the padded tail, so those blocks need a
            # state-snapshot admission path — future work.  The static
            # serve path (repro.launch.serve without --engine) still
            # covers these architectures.
            raise ValueError(
                f"ServingEngine does not support block kinds "
                f"{sorted(unsupported)} (arch {cfg.name!r}); only "
                "global-attention caches (attn/moe/shared_attn) admit "
                "correctly")
        if cfg.arch_type == "vlm" or cfg.frontend:
            # those prefill signatures require an 'embeds' input the
            # engine's token-only admission path never builds
            raise ValueError(
                f"ServingEngine serves token prompts only; arch "
                f"{cfg.name!r} (arch_type={cfg.arch_type!r}, "
                f"frontend={cfg.frontend!r}) needs embedding inputs")
        self.cfg, self.mesh, self.params = cfg, mesh, params
        self.n_slots, self.prefill_len = n_slots, prefill_len
        self.prefill_mode = prefill_mode
        self.chunk_len = max(1, min(chunk_len, prefill_len))
        self.pad_id, self._clock = pad_id, clock

        self._step, lay_d, _, _ = make_serve_step(
            cfg, mesh, params, batch=n_slots, cap=max_cache,
            prefill_len=prefill_len, hp=hp)
        self.layout = lay_d
        # pin the decode-layout cache sharding on every path that feeds
        # the step function (its donated args reject resharding)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                cache_specs(cfg, lay_d, hp))
        if prefill_mode == "chunked":
            # ONE chunk program writes straight into the decode cache
            # at runtime offsets — no prefill-layout cache, no grow, no
            # insert round trip
            self._chunk, lay_c, _ = make_chunk_prefill_step(
                cfg, mesh, params, batch=n_slots, cap=max_cache,
                prefill_len=prefill_len, chunk_len=self.chunk_len, hp=hp)
            assert lay_c == lay_d, (lay_c, lay_d)
        else:
            # legacy padded admission: monolithic flush + grow + insert
            # (make_prefill_step re-derives PrismConfig.P from the
            # layout's n_seq; only mode/cr of ``prism`` matter here)
            self._prefill, lay_p, _, _ = make_prefill_step(
                cfg, mesh, params, prism, batch=n_slots, n=prefill_len,
                hp=hp)
            assert lay_p.n_seq == lay_d.n_seq, (lay_p, lay_d)
            self._grow = jax.jit(
                functools.partial(grow_cache, lay_from=lay_p, lay_to=lay_d),
                out_shardings=cache_sh)
            self._insert = jax.jit(insert_cache_row, donate_argnums=(0,),
                                   out_shardings=cache_sh)
        self._cache = jax.device_put(init_cache(cfg, lay_d, n_slots, hp),
                                     cache_sh)

        self._sched = FifoScheduler(n_slots,
                                    decode_per_prefill=decode_per_prefill,
                                    gang=gang)
        self.stats = EngineStats(n_slots=n_slots)
        self._pending: list = []       # heap of (arrival, rid, Request)
        self._results: dict = {}       # rid -> RequestState
        self._next_rid = 0
        self._t0 = None                # clock origin (first submit/run)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def submit(self, prompt, *, max_new_tokens: int, eos_id=None,
               sampling: SamplingParams = SamplingParams(),
               arrival: float | None = None) -> int:
        """Queue one request.  ``arrival`` (engine-relative seconds) may
        lie in the future — the run loop holds the request back until
        the clock passes it, which is how Poisson traces are replayed.
        """
        prompt = tuple(int(t) for t in prompt)
        if not 1 <= len(prompt) <= self.prefill_len:
            raise ValueError(
                f"prompt length {len(prompt)} not in [1, {self.prefill_len}]")
        if len(prompt) + max_new_tokens > self.layout.cap:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new_tokens} exceeds "
                f"cache capacity {self.layout.cap}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
                      eos_id=eos_id, sampling=sampling,
                      arrival=self.now() if arrival is None else arrival)
        # always route through the arrival-ordered pending heap so a
        # late submit with an already-past arrival cannot jump ahead of
        # earlier arrivals still waiting to be released (FIFO by
        # arrival time; rid breaks ties in submit order)
        heapq.heappush(self._pending, (req.arrival, rid, req))
        self._release_arrivals()
        return rid

    def _release_arrivals(self):
        now = self.now()
        while self._pending and self._pending[0][0] <= now:
            self._sched.submit(heapq.heappop(self._pending)[2])
        self._sched.drain = not self._pending

    def next_arrival(self) -> float | None:
        """Arrival time of the earliest not-yet-released request —
        what an external drive loop (logical-clock benchmarks) jumps
        the clock to when the engine reports 'idle'."""
        return self._pending[0][0] if self._pending else None

    # ------------------------------------------------------------------
    # one engine iteration
    # ------------------------------------------------------------------
    def step(self) -> str:
        """Run one scheduler decision: a prefill chunk (padded mode: an
        admission flush), a decode step, or nothing ('idle').  Returns
        which."""
        sch = self._sched
        self._release_arrivals()
        if self.stats.t_start is None:
            self.stats.t_start = self.now()

        if self.prefill_mode == "padded":
            if sch.want_prefill():
                return self._padded_flush()
        else:
            if sch.want_admit():
                sch.admit(self.now())      # host-side: assign slots only
            if sch.want_chunk():
                return self._chunk_step()

        decoding = sch.decoding()
        if decoding:
            tok = np.zeros(self.n_slots, np.int32)
            pos = np.full(self.n_slots, -1, np.int32)
            for st in decoding:
                tok[st.slot] = st.next_token
                pos[st.slot] = st.pos
            t0 = self.now()
            logits, self._cache = self._step(
                self.params, self._cache, jnp.asarray(tok), jnp.asarray(pos))
            rows = np.asarray(jax.device_get(logits))
            now = self.now()
            self.stats.step_latency.append(now - t0)
            self.stats.occupancy.append(len(sch.active) / self.n_slots)
            self.stats.decode_steps += 1
            for st in decoding:
                t = sample_token(rows[st.slot], st.req.sampling, st.rng)
                st.generated.append(t)
                self.stats.generated_tokens += 1
                if st.ttft is None:
                    st.ttft = now - st.req.arrival
                    self.stats.ttft.append(st.ttft)
                st.pos += 1
                st.next_token = t
                if st.finished():
                    sch.evict(st, now)
                    self._results[st.req.rid] = st
                    self.stats.completed += 1
            sch.note_decode()
            self.stats.t_end = self.now()
            return "decode"
        return "idle"

    def _chunk_step(self) -> str:
        """Advance EVERY mid-prefill request by one chunk (each at its
        own offset) in a single compiled call."""
        sch = self._sched
        c = self.chunk_len
        tokens = np.full((self.n_slots, c), self.pad_id, np.int32)
        off = np.full(self.n_slots, -1, np.int32)
        nreal = np.zeros(self.n_slots, np.int32)
        states = sch.prefilling()
        for st in states:
            take = min(c, len(st.req.prompt) - st.nprefilled)
            tokens[st.slot, :take] = st.req.prompt[
                st.nprefilled:st.nprefilled + take]
            off[st.slot] = st.nprefilled
            nreal[st.slot] = take
        self._cache = self._chunk(self.params, self._cache,
                                  jnp.asarray(tokens), jnp.asarray(off),
                                  jnp.asarray(nreal))
        for st in states:
            st.nprefilled += int(nreal[st.slot])
            if not st.prefilling:
                st.begin_decode()          # rewind: re-feed last token
        sch.note_chunk()
        self.stats.prefills += 1
        self.stats.prefill_chunks += 1
        self.stats.prefill_tokens += int(nreal.sum())
        self.stats.t_end = self.now()
        return "prefill"

    def _padded_flush(self) -> str:
        """Legacy admission: right-pad every admitted prompt to
        ``prefill_len``, one monolithic prefill, grow + splice each row
        into its slot, start decoding at the rewind position."""
        sch = self._sched
        batch = np.full((self.n_slots, self.prefill_len), self.pad_id,
                        np.int32)
        states = sch.admit(self.now())
        for i, st in enumerate(states):
            batch[i, :len(st.req.prompt)] = st.req.prompt
        _, fresh = self._prefill(self.params, {"tokens":
                                               jnp.asarray(batch)})
        grown = self._grow(fresh)
        for i, st in enumerate(states):
            self._cache = self._insert(self._cache, grown,
                                       jnp.asarray(i, jnp.int32),
                                       jnp.asarray(st.slot, jnp.int32))
            st.begin_decode()
            self.stats.prefill_tokens += len(st.req.prompt)
        self.stats.prefills += 1
        self.stats.t_end = self.now()
        return "prefill"

    # ------------------------------------------------------------------
    # drive to completion
    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Step until every submitted request (including future
        arrivals) has finished.  Returns {rid: [generated token ids]}."""
        while True:
            kind = self.step()
            if kind != "idle":
                continue
            if self._pending:
                # nothing runnable until the next arrival — wait it
                # out.  An injected clock that doesn't tick with wall
                # time (e.g. a logical StepClock) is fast-forwarded to
                # the arrival instead, so run() terminates under both.
                before = self.now()
                dt = self.next_arrival() - before
                if dt > 0:
                    time.sleep(min(dt, 0.05))
                    if self.now() <= before:
                        self._t0 -= dt
                continue
            if not self._sched.has_work:
                break
        return self.results()

    def results(self) -> dict:
        return {rid: list(st.generated)
                for rid, st in sorted(self._results.items())}

    def request_stats(self) -> dict:
        return {rid: {"ttft_s": st.ttft,
                      "latency_s": (st.t_finish - st.req.arrival
                                    if st.t_finish is not None else None),
                      "tokens": len(st.generated)}
                for rid, st in sorted(self._results.items())}

"""Request-level serving: continuous batching over the sequence-sharded
decode runtime (docs/serving.md), plus the overlapped async streaming
front-end (docs/streaming.md)."""
from ..runtime.faults import FaultInjector, FaultPlan, FaultSpec
from ..runtime.offload import KVStore, SpilledEntry
from .sampling import SamplingParams, sample_token
from .scheduler import Request, RequestState, FifoScheduler, EngineStats
from .engine import EngineConfig, EngineSnapshot, ServingEngine
from .streaming import (ResultTokens, StreamingEngine, TokenStream,
                        serve_stream)

__all__ = ["SamplingParams", "sample_token", "Request", "RequestState",
           "FifoScheduler", "EngineStats", "EngineConfig",
           "EngineSnapshot", "ServingEngine", "KVStore", "SpilledEntry",
           "FaultInjector", "FaultPlan", "FaultSpec", "ResultTokens",
           "StreamingEngine", "TokenStream", "serve_stream"]

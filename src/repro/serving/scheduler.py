"""Request-level scheduling for the continuous-batching engine.

FIFO admission over a fixed pool of decode slots.  Admission assigns a
slot immediately (it is host-side bookkeeping); the prompt is then
prefilled either *packed* or *in chunks*.

In the default **packed** mode the scheduler is a Sarathi-style
token-budget planner: ``plan_tick(token_budget)`` gives every decoding
slot its one decode token first, then fills the remaining budget with
prompt-chunk tokens across *all* mid-prefill requests (ascending slot
order) — one flat ragged batch per engine tick, consumed by ONE
compiled program.  Decode streams are structurally protected (their
token is always in the tick), so the ``decode_per_prefill`` interleave
bound is retired in packed mode.

In the legacy **chunked** mode the scheduler interleaves chunk steps
with decode steps: once streams are decoding, at most one chunk per
``decode_per_prefill`` decode steps, so a long prompt (or a burst of
arrivals) can never starve running streams of decode bandwidth for
more than a bounded number of steps.  An engine with nothing decoding
always chunks immediately — there is no decode work to protect, and
TTFT is all that matters.  All mid-prefill rows advance *together* in
one batched chunk call (each at its own offset), so concurrent
admissions don't serialize.

The legacy ``padded`` engine mode still uses the all-or-nothing policy
(``want_prefill``): one whole pad-to-``prefill_len`` flush per
``decode_per_prefill`` decode steps.

``gang=True`` degrades admission to classic *static batching* — admit
only into an empty pool, then drain it completely — which is the
baseline the engine-throughput benchmark compares against.

**Priority classes and preemption** (PR 7): every request carries an
integer ``priority`` (higher = more urgent; default 0 keeps the
scheduler exactly FIFO).  Admission serves the highest non-empty class
first; within a class, *resume* candidates (requests preempted earlier,
ordered by original arrival) go before fresh ones, and fresh ones stay
FIFO.  ``pick_victim`` implements the preempt policy: when a
higher-priority candidate would otherwise block (no slot, or
``out_of_pages``), the engine spills the lowest-priority
longest-remaining active request to the host KV store
(``runtime/offload.py``) and parks its RequestState on the resume
queue.  A preempted request keeps its RequestState — generated tokens,
decode position, and sampler RNG survive, so a restore continues
bit-identically.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from .sampling import SamplingParams


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: tuple
    max_new_tokens: int
    eos_id: int | None = None
    sampling: SamplingParams = SamplingParams()
    arrival: float = 0.0             # absolute clock time of arrival
    priority: int = 0                # higher = more urgent; 0 = default
    deadline: float | None = None    # absolute clock time; None = never


class RequestState:
    """Mutable per-request serving state while a request owns a slot.

    A request starts in the *prefill* phase: ``nprefilled`` counts the
    prompt tokens already laid down (the engine advances it one chunk
    at a time; the legacy padded mode jumps it to the full length in
    one flush).  ``begin_decode`` performs the *rewind* to the decode
    phase: the slot starts at ``pos = len(prompt) - 1`` and re-feeds
    the final prompt token — the first decode step rewrites that K/V
    row in place (an idempotent rewrite: chunked prefill already wrote
    it, and the computation is identical) and returns the exact
    teacher-forced next-token logits.  Everything past ``pos`` is
    invisible (``col_pos <= pos``) until real decoded tokens land there.
    """
    __slots__ = ("req", "slot", "pos", "next_token", "nprefilled",
                 "generated", "rng", "t_admit", "ttft", "t_finish",
                 "restarts", "epoch", "inflight")

    def __init__(self, req: Request, slot: int, t_admit: float):
        self.req = req
        self.slot = slot
        self.pos = -1                  # decode position; -1 while prefilling
        self.next_token = None
        self.nprefilled = 0            # prompt tokens laid down so far
        self.generated: list = []
        self.rng = req.sampling.make_rng()
        self.t_admit = t_admit
        self.ttft = None
        self.t_finish = None
        self.restarts = 0              # re-prefills after a lost restore
        self.epoch = 0                 # bumped on every rewind-to-zero;
        # the streaming engine stamps in-flight rows with it so results
        # that raced a quarantine/restart reconcile as stale
        self.inflight = 0              # dispatched, not-yet-reconciled rows

    @property
    def prefilling(self) -> bool:
        return self.nprefilled < len(self.req.prompt)

    @property
    def remaining(self) -> int:
        """Tokens of work left (prompt still to prefill + tokens still
        to generate) — the preempt policy's tie-breaker."""
        return ((len(self.req.prompt) - self.nprefilled)
                + (self.req.max_new_tokens - len(self.generated)))

    def reset_for_refill(self):
        """Restart from scratch after the offload store lost this
        request's spilled KV (host-memory pressure): clean per-request
        recovery — re-prefill the prompt, regenerate from a fresh
        sampler RNG (greedy/seeded sampling makes the rerun
        deterministic).  ``ttft`` is NOT cleared: time-to-FIRST-token
        was already observed and must not be double-counted."""
        self.pos = -1
        self.next_token = None
        self.nprefilled = 0
        self.generated = []
        self.rng = self.req.sampling.make_rng()
        self.restarts += 1
        self.epoch += 1                # invalidate in-flight rows

    def begin_decode(self):
        """Prefill done — rewind to the last prompt token and decode."""
        self.nprefilled = len(self.req.prompt)
        self.pos = len(self.req.prompt) - 1
        self.next_token = int(self.req.prompt[-1])

    def finished(self) -> bool:
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        eos = self.req.eos_id
        return eos is not None and self.generated and self.generated[-1] == eos


#: ring-buffer cap on the per-step/per-request sample lists — a
#: long-running engine must not grow host memory without bound;
#: percentiles over the most recent window are what an operator wants
#: anyway
STATS_WINDOW = 65536


@dataclass
class EngineStats:
    """Throughput/latency counters the engine accumulates as it runs.
    Sample lists are bounded deques (see ``STATS_WINDOW``)."""
    n_slots: int = 0
    ttft: deque = field(                               # arrival -> 1st token
        default_factory=lambda: deque(maxlen=STATS_WINDOW))
    step_latency: deque = field(                       # per decode step (s)
        default_factory=lambda: deque(maxlen=STATS_WINDOW))
    occupancy: deque = field(                          # active/slots per step
        default_factory=lambda: deque(maxlen=STATS_WINDOW))
    prefills: int = 0                  # prefill program calls (flush/chunk)
    prefill_chunks: int = 0            # chunked-mode calls among them
    prefill_tokens: int = 0            # REAL prompt tokens laid down
    chunk_tokens_real: int = 0         # real rows×tokens in chunk calls
    chunk_tokens_padded: int = 0       # padded waste in chunk calls
    decode_steps: int = 0
    packed_ticks: int = 0              # packed-program calls
    packed_decode_tokens: int = 0      # real decode tokens packed
    packed_prefill_tokens: int = 0     # real prompt tokens packed
    completed: int = 0
    generated_tokens: int = 0
    out_of_pages: int = 0              # admissions blocked on the free list
    prefix_hits: int = 0               # admissions that mapped a prefix
    prefix_tokens_saved: int = 0       # prompt tokens never prefilled
    preemptions: int = 0               # spills to the host KV store
    spilled_pages: int = 0             # pages gathered device -> host
    restore_hits: int = 0              # resumes injected from the store
    restore_misses: int = 0            # resumes re-prefilled (entry lost)
    restarts: int = 0                  # reset_for_refill invocations
    deadline_miss: int = 0             # requests cancelled past deadline
    deadline_miss_by_class: dict = field(default_factory=dict)
    quarantined: int = 0               # non-finite decode rows caught
    failed_requests: int = 0           # max_restarts / unrecoverable
    faults_injected: int = 0           # chaos faults actually fired
    faults_by_kind: dict = field(default_factory=dict)  # kind -> fired
    store_get_retries: int = 0         # KVStore reads re-tried (restore)
    shard_lost: int = 0                # shard_loss faults entered degraded
    degraded_ticks: int = 0            # ticks served in degraded mode
    cancelled: int = 0                 # requests cancelled by the caller
    ticks_idle: int = 0                # step() calls that found no work
    tokens_streamed: int = 0           # tokens delivered to TokenStreams
    host_busy_s: float = 0.0           # host-side bookkeeping (streaming)
    loop_wall_s: float = 0.0           # total non-idle streaming wall time
    t_start: float | None = None
    t_end: float | None = None

    def summary(self) -> dict:
        span = ((self.t_end - self.t_start)
                if self.t_start is not None and self.t_end is not None
                else 0.0)
        pct = (lambda xs, q: float(np.percentile(list(xs), q))
               if xs else 0.0)
        return {
            "requests": self.completed,
            "elapsed_s": span,
            "requests_per_s": self.completed / span if span else 0.0,
            "decode_tokens_per_s": (self.generated_tokens / span
                                    if span else 0.0),
            "ttft_p50_s": pct(self.ttft, 50),
            "ttft_p90_s": pct(self.ttft, 90),
            "ttft_p99_s": pct(self.ttft, 99),
            "ttft_max_s": max(self.ttft) if self.ttft else 0.0,
            "step_ms_p50": 1e3 * pct(self.step_latency, 50),
            "occupancy": (float(np.mean(self.occupancy))
                          if self.occupancy else 0.0),
            "prefills": self.prefills,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "chunk_tokens_real": self.chunk_tokens_real,
            "chunk_tokens_padded": self.chunk_tokens_padded,
            "decode_steps": self.decode_steps,
            "packed_ticks": self.packed_ticks,
            "packed_decode_tokens": self.packed_decode_tokens,
            "packed_prefill_tokens": self.packed_prefill_tokens,
            "out_of_pages": self.out_of_pages,
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_saved": self.prefix_tokens_saved,
            "preemptions": self.preemptions,
            "spilled_pages": self.spilled_pages,
            "restore_hits": self.restore_hits,
            "restore_misses": self.restore_misses,
            "restarts": self.restarts,
            "deadline_miss": self.deadline_miss,
            "deadline_miss_by_class": {
                str(k): v for k, v
                in sorted(self.deadline_miss_by_class.items())},
            "quarantined": self.quarantined,
            "failed_requests": self.failed_requests,
            "faults_injected": self.faults_injected,
            "faults_by_kind": {k: v for k, v
                               in sorted(self.faults_by_kind.items())},
            "store_get_retries": self.store_get_retries,
            "shard_lost": self.shard_lost,
            "degraded_ticks": self.degraded_ticks,
            "cancelled": self.cancelled,
            "ticks_idle": self.ticks_idle,
            "tokens_streamed": self.tokens_streamed,
            # host-side bookkeeping share of the streaming loop's wall
            # time (0 when the engine never ran the streaming loop) —
            # the overlap-efficiency number docs/streaming.md defines
            "host_overhead_fraction": (self.host_busy_s / self.loop_wall_s
                                       if self.loop_wall_s > 0 else 0.0),
        }


class FifoScheduler:
    """FIFO-within-priority queue + slot pool + interleave policy.

    With every request at the default priority 0 and no preemption this
    is exactly the original FIFO scheduler (same admission order, same
    interleave bounds).  Priorities add per-class queues; preemption
    adds per-class *resume* queues of parked RequestStates."""

    def __init__(self, n_slots: int, *, decode_per_prefill: int = 4,
                 gang: bool = False):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.decode_per_prefill = max(1, decode_per_prefill)
        self.gang = gang
        self.queues: dict = {}         # priority -> deque[Request], FIFO
        self.resume: dict = {}         # priority -> [RequestState] by arrival
        self.free_slots: list = list(range(n_slots))   # ascending order
        self.active: dict = {}                         # slot -> RequestState
        self.drain = False     # no more arrivals expected (gang flushes)
        self._decodes_since_prefill = self.decode_per_prefill

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request):
        self.queues.setdefault(req.priority, deque()).append(req)

    @property
    def queued(self) -> int:
        """Pending admissions: fresh requests + parked preemptees."""
        return (sum(len(q) for q in self.queues.values())
                + sum(len(q) for q in self.resume.values()))

    @property
    def has_work(self) -> bool:
        return bool(self.queued or self.active)

    # -- views -------------------------------------------------------------
    def prefilling(self) -> list:
        """Mid-prefill RequestStates, ascending slot order."""
        return [st for _, st in sorted(self.active.items())
                if st.prefilling]

    def decoding(self) -> list:
        """Decode-phase RequestStates, ascending slot order."""
        return [st for _, st in sorted(self.active.items())
                if not st.prefilling]

    # -- policy ------------------------------------------------------------
    def _gang_ready(self) -> bool:
        """Static batching admits only a full gang into an EMPTY pool
        (or the drain-time remainder once no more arrivals come)."""
        return not self.active and (self.queued >= self.n_slots
                                    or self.drain)

    def want_admit(self) -> bool:
        """Chunked mode: admission is host-side bookkeeping (assign a
        slot, start chunking under the interleave policy), so it is
        never rate-limited — except in gang mode."""
        if not self.queued or not self.free_slots:
            return False
        return self._gang_ready() if self.gang else True

    def plan_tick(self, token_budget: int) -> tuple:
        """Sarathi-style token-budget plan for one packed tick:
        every decoding slot contributes its one decode token first
        (structural fairness — decodes are never starved), then the
        remaining budget fills with prompt-chunk tokens across ALL
        mid-prefill requests in ascending slot order, each request
        taking ``min(remaining prompt, remaining budget)``.  Returns
        ``(decode_states, [(prefill_state, n_tokens), ...])``; the
        total never exceeds ``token_budget``."""
        decode = self.decoding()
        budget = token_budget - len(decode)
        assert budget >= 0, (
            f"token_budget {token_budget} < {len(decode)} decoding "
            "slots — the engine must keep token_budget >= n_slots")
        prefill = []
        for st in self.prefilling():
            if budget <= 0:
                break
            take = min(budget, len(st.req.prompt) - st.nprefilled)
            if take > 0:
                prefill.append((st, take))
                budget -= take
        return decode, prefill

    def want_chunk(self) -> bool:
        """Run a prefill chunk now?  Always when nothing is decoding;
        otherwise at most one chunk per ``decode_per_prefill`` decode
        steps — the bound on how long a long prompt can hold decode
        bandwidth away from running streams."""
        if not any(st.prefilling for st in self.active.values()):
            return False
        if not any(not st.prefilling for st in self.active.values()):
            return True
        return self._decodes_since_prefill >= self.decode_per_prefill

    def want_prefill(self) -> bool:
        """Legacy padded mode: admit + full pad-to-length flush as one
        all-or-nothing step, same interleave bound."""
        if not self.queued or not self.free_slots:
            return False
        if self.gang:
            return self._gang_ready()
        if not self.active:
            return True
        return self._decodes_since_prefill >= self.decode_per_prefill

    def note_decode(self):
        self._decodes_since_prefill += 1

    def note_chunk(self):
        self._decodes_since_prefill = 0

    # -- admission order ---------------------------------------------------
    def peek_admit(self):
        """Next admission candidate without popping it: highest
        non-empty priority class first; within a class, resume
        candidates (parked RequestStates, ordered by original arrival —
        they already hold progress, evicting them forever would starve
        them) before fresh Requests, each FIFO.  Returns a Request, a
        RequestState, or None."""
        for prio in sorted(set(self.resume) | set(self.queues),
                           reverse=True):
            if self.resume.get(prio):
                return self.resume[prio][0]
            if self.queues.get(prio):
                return self.queues[prio][0]
        return None

    def _pop_head(self, cand) -> None:
        """Remove the candidate ``peek_admit`` just returned."""
        if isinstance(cand, RequestState):
            q = self.resume[cand.req.priority]
            assert q[0] is cand
            q.pop(0)
        else:
            q = self.queues[cand.priority]
            assert q[0] is cand
            q.popleft()

    # -- transitions -------------------------------------------------------
    def admit(self, now: float, gate=None) -> list:
        """Pop admission candidates into free slots (lowest slot first)
        and return the admitted RequestStates, in admission order.
        Candidate order is ``peek_admit``'s: priority classes high to
        low, resumes before fresh, FIFO within each.

        ``gate(candidate) -> bool`` is the page-aware admission check:
        it is consulted on the head candidate before the pop, and a
        False stops admission for this call (strict order — later,
        smaller requests never jump an out-of-pages head; the engine
        retries next tick once eviction, prefix reclaim, or preemption
        refills the free list).  A True gate may reserve resources, so
        the pop must follow it.  The candidate is a Request (fresh) or
        a RequestState (resume from the offload store); a resumed state
        keeps its progress and is re-bound to the new slot."""
        states = []
        while self.free_slots:
            cand = self.peek_admit()
            if cand is None:
                break
            if gate is not None and not gate(cand):
                break
            self._pop_head(cand)
            slot = self.free_slots.pop(0)
            if isinstance(cand, RequestState):
                st = cand
                st.slot = slot
            else:
                st = RequestState(cand, slot, now)
            self.active[slot] = st
            states.append(st)
        if states:
            self._decodes_since_prefill = 0
        return states

    def evict(self, st: RequestState, now: float):
        """Release a finished request's slot back to the pool."""
        assert self.active.get(st.slot) is st
        del self.active[st.slot]
        st.t_finish = now
        self.free_slots.append(st.slot)
        self.free_slots.sort()

    # -- preemption --------------------------------------------------------
    def pick_victim(self, below_priority: int, now: float = 0.0):
        """Preempt policy: among active requests with priority strictly
        below ``below_priority``, pick the lowest-priority one with the
        most deadline slack (``deadline - now - remaining``; no deadline
        counts as infinite slack — SLO-less work is always preempted
        before anything racing a deadline), then the most work
        remaining (ties: highest rid, i.e. latest arrival).
        Decode-phase requests are preferred victims — spilling one
        frees a full row at zero recompute; a mid-prefill victim is
        chosen only when nothing is decoding.  Returns None when no
        strictly-lower-priority victim exists (equal-priority
        preemption would thrash: the pool drains by itself)."""
        cands = [st for st in self.active.values()
                 if st.req.priority < below_priority]
        if not cands:
            return None
        decode = [st for st in cands if not st.prefilling]
        pool = decode or cands

        def slack(st):
            if st.req.deadline is None:
                return float("inf")
            return st.req.deadline - now - st.remaining

        return max(pool, key=lambda st: (-st.req.priority, slack(st),
                                         st.remaining, st.req.rid))

    def remove(self, st: RequestState) -> None:
        """Detach an active request from its slot WITHOUT finishing it
        (the spill half of preempt/suspend — the caller owns where the
        RequestState goes next)."""
        assert self.active.get(st.slot) is st
        del self.active[st.slot]
        self.free_slots.append(st.slot)
        self.free_slots.sort()
        st.slot = -1

    def push_resume(self, st: RequestState) -> None:
        """Park a spilled RequestState for re-admission, keeping the
        class's resume queue ordered by original arrival (fair resume
        ordering: earliest-arrived preemptee restores first no matter
        how many times it was bounced)."""
        q = self.resume.setdefault(st.req.priority, [])
        q.append(st)
        q.sort(key=lambda s: (s.req.arrival, s.req.rid))

    def preempt(self, st: RequestState) -> None:
        """Spill-side bookkeeping: free the slot and queue the state
        for automatic resume (the engine spills the KV footprint to the
        store before calling this)."""
        self.remove(st)
        self.push_resume(st)

    def cancel(self, rid: int):
        """Remove a not-yet-active request (queued fresh or parked for
        resume) by rid.  Returns the removed Request/RequestState, or
        None if the rid is not waiting here.  Active requests are
        cancelled by the engine (``ServingEngine.cancel`` frees their
        pages/slot); this method only covers the queued states."""
        for q in self.queues.values():
            for req in q:
                if req.rid == rid:
                    q.remove(req)
                    return req
        for q in self.resume.values():
            for st in q:
                if st.req.rid == rid:
                    q.remove(st)
                    return st
        return None

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against ShapeDtypeStruct inputs — no allocation — and
extract the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k [--multipod] [--mode prism|voltage] [--json out]

Shapes of kind 'train' lower ``train_step``; 'prefill' lowers the prefill
forward; 'decode' lowers ``serve_step`` (ONE new token against a seq_len
KV cache).  Success = .compile() returns; the printed memory_analysis
proves per-device fit and cost_analysis feeds §Roofline.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.configs.shapes import SHAPES
from repro.core.protocol import PrismConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.inputs import (train_input_specs, prefill_input_specs,
                                 decode_input_specs, param_shapes,
                                 count_params, active_param_fraction)
from repro.launch.roofline import (Roofline, collective_bytes, model_flops)


def lower_train(cfg, mesh, shape, prism, dtype):
    from repro.optim import adamw_init
    from repro.runtime.train import make_train_step, TrainHParams
    params = param_shapes(cfg, dtype)
    hp = TrainHParams(remat=True, loss_chunks=16)
    step, rules, psh, osh, bsh = make_train_step(cfg, mesh, params, prism, hp)
    opt = jax.eval_shape(adamw_init, params)
    batch = train_input_specs(cfg, shape, dtype)
    return step.lower(params, opt, batch)


def lower_prefill(cfg, mesh, shape, prism, dtype):
    from repro.runtime.serve import make_prefill_step, ServeHParams
    params = param_shapes(cfg, dtype)
    hp = ServeHParams(decode_mode="prism" if prism.mode == "prism"
                      else "exact", means_cr=prism.cr)
    step, lay, rules, lspec = make_prefill_step(
        cfg, mesh, params, prism, batch=shape.global_batch,
        n=shape.seq_len, hp=hp)
    batch = prefill_input_specs(cfg, shape, dtype)
    return step.lower(params, batch)


def lower_decode(cfg, mesh, shape, prism, dtype):
    import os as _os
    from repro.runtime.serve import (make_serve_step, ServeHParams,
                                     cache_shapes, make_layout)
    params = param_shapes(cfg, dtype)
    hp = ServeHParams(decode_mode="prism" if prism.mode == "prism"
                      else "exact", means_cr=prism.cr,
                      decode_tp=_os.environ.get("REPRO_DECODE_TP") == "1")
    step, lay, rules, lspec = make_serve_step(
        cfg, mesh, params, batch=shape.global_batch, cap=shape.seq_len,
        hp=hp)
    cache = cache_shapes(cfg, lay, shape.global_batch, hp, dtype)
    token, pos = decode_input_specs(cfg, shape)
    return step.lower(params, cache, token, pos)


_LOWER = {"train": lower_train, "prefill": lower_prefill,
          "decode": lower_decode}


def _one_compile(cfg, mesh, shape, prism, dtype):
    lowered = _LOWER[shape.kind](cfg, mesh, shape, prism, dtype)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return compiled, {"flops": float(cost.get("flops", 0.0)),
                      "bytes": float(cost.get("bytes accessed", 0.0)),
                      **{k: float(coll[k]) for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute", "total")}}


def extrapolated_costs(cfg, mesh, shape, prism, dtype):
    """XLA's cost_analysis counts a While (lax.scan) body ONCE, so the
    scanned-layers program under-reports.  Fit cost = base + depth·unit
    from two small UNROLLED compiles (1 and 2 units) and evaluate at the
    real depth — exact for repeated identical layers."""
    from dataclasses import replace
    u, n_units, n_tail = cfg.scan_split
    if n_units == 1:                      # already unrolled: trip count 1
        return None
    kinds = cfg.block_kinds
    c1 = replace(cfg, n_layers=u, blocks=kinds[:u], scan_layers=False)
    c2 = replace(cfg, n_layers=2 * u, blocks=kinds[:u] * 2,
                 scan_layers=False)
    _, m1 = _one_compile(c1, mesh, shape, prism, dtype)
    _, m2 = _one_compile(c2, mesh, shape, prism, dtype)
    depth_units = cfg.n_layers / u
    out = {}
    for k in m1:
        unit = m2[k] - m1[k]
        base = m1[k] - unit
        out[k] = max(0.0, base + depth_units * unit)
    return out


def run_one(arch: str, shape_name: str, *, multipod: bool, mode: str,
            cr: float, dtype=jnp.bfloat16, verbose: bool = True):
    cfg = get_config(arch)
    blk = int(os.environ.get("REPRO_ATTN_BLOCK", "0"))
    if blk:                               # §Perf H3: streaming attention
        from dataclasses import replace as _rep
        cfg = _rep(cfg, attn_block=blk)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multipod)
    chips = mesh.devices.size
    prism = PrismConfig(P=1, cr=cr, mode=mode)   # P is taken from the mesh

    t0 = time.time()
    lowered = _LOWER[shape.kind](cfg, mesh, shape, prism, dtype)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    fit = extrapolated_costs(cfg, mesh, shape, prism, dtype)
    if fit is not None:
        cost = {"flops": fit["flops"], "bytes accessed": fit["bytes"]}
        coll = {k: fit[k] for k in ("all-gather", "all-reduce",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute", "total")}
        coll["ops"] = "extrapolated(base + depth*unit)"

    pshapes = param_shapes(cfg, dtype)
    n_params = count_params(pshapes)
    frac = active_param_fraction(cfg, pshapes)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(shape.kind, int(n_params * frac), tokens)

    rl = Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multipod else "16x16", mode=mode,
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll["total"]),
        model_flops=mf, chips=chips)

    rec = rl.row()
    rec.update(
        t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
        n_params=n_params, active_frac=round(frac, 4),
        coll_detail={k: v for k, v in coll.items() if k != "ops"},
        coll_ops=coll["ops"],
        mem_argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        mem_output_bytes=getattr(mem, "output_size_in_bytes", None),
        mem_temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        # peak LIVE set (args + max live temps) — the fits-in-HBM check;
        # temp_size is the SUM of temp allocations, not simultaneous
        mem_peak_bytes=getattr(mem, "peak_memory_in_bytes", None),
    )
    if verbose:
        print(f"== {arch} × {shape_name} × {rec['mesh']} [{mode}] ==")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={rec['mem_argument_bytes']}, "
              f"temp={rec['mem_temp_bytes']}, out={rec['mem_output_bytes']}")
        print(f"  cost_analysis: flops/dev={rl.flops:.3e}, "
              f"bytes/dev={rl.bytes_accessed:.3e}")
        print(f"  collectives/dev: {rec['coll_detail']}")
        print(f"  roofline: compute={rl.t_compute * 1e3:.2f}ms "
              f"memory={rl.t_memory * 1e3:.2f}ms "
              f"collective={rl.t_collective * 1e3:.2f}ms "
              f"-> {rl.bottleneck}-bound")
        print(f"  MODEL_FLOPS={mf:.3e} useful_frac="
              f"{rl.useful_flops_frac:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help=f"one of {ASSIGNED_ARCHS} or 'all'")
    ap.add_argument("--shape", required=True,
                    help=f"one of {tuple(SHAPES)} or 'all'")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mode", default="prism",
                    choices=("prism", "voltage"))
    ap.add_argument("--cr", type=float, default=16.0)
    ap.add_argument("--json", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)
    failures = []
    for a in archs:
        for s in shapes:
            try:
                rec = run_one(a, s, multipod=args.multipod, mode=args.mode,
                              cr=args.cr)
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failures.append((a, s, repr(e)[:500]))
                print(f"== {a} × {s} FAILED: {e!r}"[:600])
    if failures:
        print(f"{len(failures)} FAILURES")
        sys.exit(1)
    print("DRY-RUN OK")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation.  The dry-run lowers against these.

``[audio]``/``[vlm]`` carve-out: the modality frontend is a stub —
``input_specs`` provides precomputed frame/patch embeddings of the right
shape, and the framework implements the transformer that consumes them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.shapes import SHAPES, InputShape
from ..models.config import ModelConfig


def train_input_specs(cfg: ModelConfig, shape: InputShape,
                      dtype=jnp.bfloat16):
    b, n = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "encodec_stub":          # audio: frame embeddings
        return {"embeds": sds((b, n, cfg.d_model), dtype),
                "labels": sds((b, n), jnp.int32)}
    batch = {"tokens": sds((b, n), jnp.int32),
             "labels": sds((b, n), jnp.int32)}
    if cfg.arch_type == "vlm":                  # image-patch prefix
        batch["embeds"] = sds((b, cfg.prefix_len, cfg.d_model), dtype)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: InputShape,
                        dtype=jnp.bfloat16):
    b, n = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "encodec_stub":
        return {"embeds": sds((b, n, cfg.d_model), dtype)}
    batch = {"tokens": sds((b, n), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["embeds"] = sds((b, cfg.prefix_len, cfg.d_model), dtype)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    """(token, pos) — the cache SDS tree comes from serve.cache_shapes.
    ``pos`` is per-slot (B,): slots decode at independent depths."""
    b = shape.global_batch
    return (jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32))


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    """eval_shape of init — the parameter SDS tree, no allocation."""
    from ..models import transformer as T
    return jax.eval_shape(
        lambda: T.init(cfg, jax.random.PRNGKey(0), dtype))


def count_params(shapes_tree) -> int:
    import numpy as np
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes_tree)))


def active_param_fraction(cfg: ModelConfig, shapes_tree) -> float:
    """MoE: fraction of parameters active per token (top_k/n_experts on
    expert weights; 1.0 elsewhere) — for MODEL_FLOPS = 6·N_active·D."""
    import numpy as np
    if not cfg.n_experts:
        return 1.0
    total = exp_total = 0
    flat = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
    for path, leaf in flat:
        sz = int(np.prod(leaf.shape))
        total += sz
        if any(getattr(k, "key", None) == "experts" for k in path):
            exp_total += sz
    frac = cfg.top_k / cfg.n_experts
    return (total - exp_total * (1 - frac)) / total

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2-small \
        --steps 200 --batch 8 --seq 256 --mesh 2x4 --mode prism --cr 4

Uses host devices (set XLA_FLAGS=--xla_force_host_platform_device_count=N
to exceed the physical count); on a real TPU slice the same entry point
picks up the platform devices.  The production 16x16 / 2x16x16 meshes are
exercised via ``repro.launch.dryrun`` (this container compiles but cannot
execute 256-chip programs).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="2x4", help="DATAxMODEL")
    ap.add_argument("--mode", default="prism",
                    choices=("prism", "voltage", "single"))
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.core.protocol import PrismConfig
    from repro.data.pipeline import CharTokenizer, lm_batches, synthetic_text
    from repro.models import transformer as T
    from repro.optim import adamw_init
    from repro.runtime.train import make_train_step, TrainHParams

    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((data, model), ("data", "model"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"mesh={data}x{model} mode={args.mode} cr={args.cr}")

    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {n_params / 1e6:.1f}M params")

    prism = PrismConfig(P=model, cr=args.cr, mode=args.mode)
    hp = TrainHParams(lr=args.lr, total_steps=args.steps,
                      warmup=max(1, args.steps // 10))
    step, rules, psh, osh, bsh = make_train_step(cfg, mesh, params, prism, hp)
    params = jax.device_put(params, psh)
    opt = jax.device_put(adamw_init(params), osh)

    tok = CharTokenizer()
    corpus = tok.encode(synthetic_text(500_000, seed=1))
    it = lm_batches(corpus, batch=args.batch, seq=args.seq, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        x, y = next(it)
        batch = jax.device_put({"tokens": x, "labels": y}, bsh)
        params, opt, metrics = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['gnorm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(time.time() - t0):.1f}s")
    if args.checkpoint:
        from repro.checkpoint.io import save_checkpoint
        path = save_checkpoint(args.checkpoint, args.steps,
                               jax.device_get(params))
        print(f"[train] saved {path}")


if __name__ == "__main__":
    main()

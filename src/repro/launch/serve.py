"""Serving launcher: prefill a batch of prompts, then decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small \
        --reduced --batch 8 --prompt-len 64 --gen 16 --mesh 2x4 \
        --decode-mode exact
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--decode-mode", default="exact",
                    choices=("exact", "prism"))
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.protocol import PrismConfig
    from repro.models import transformer as T
    from repro.runtime.serve import (ServeHParams, grow_cache,
                                     make_prefill_step, make_serve_step)

    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((data, model), ("data", "model"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    if args.checkpoint:
        from repro.checkpoint.io import restore_checkpoint, latest_step
        step_n = latest_step(args.checkpoint)
        params = restore_checkpoint(args.checkpoint, step_n, params)
        print(f"[serve] restored step {step_n}")

    n_seq = model
    n = args.prompt_len - args.prompt_len % n_seq
    cap = n + args.gen + (-(n + args.gen)) % n_seq
    hp = ServeHParams(decode_mode=args.decode_mode, means_cr=args.cr)
    prism = PrismConfig(
        P=model, cr=args.cr,
        mode="prism" if args.decode_mode == "prism" else "voltage")

    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=(args.batch, n)).astype(np.int32)

    prefill, lay_p, _, _ = make_prefill_step(
        cfg, mesh, params, prism, batch=args.batch, n=n, hp=hp)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    logits.block_until_ready()
    print(f"[serve] prefill {args.batch}x{n}: {time.time() - t0:.2f}s "
          f"({args.decode_mode} cache)")

    step, lay_d, _, _ = make_serve_step(
        cfg, mesh, params, batch=args.batch, cap=cap, prefill_len=n, hp=hp)
    cache = grow_cache(cache, lay_p, lay_d)

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for g in range(args.gen - 1):
        pos = jnp.asarray(n + g, jnp.int32)
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({1e3 * dt / max(1, args.gen - 1):.1f} ms/token)")
    gen = np.stack(out, axis=1)
    print("[serve] generated token ids (first 2 rows):")
    print(gen[:2])


if __name__ == "__main__":
    main()

"""Serving launcher: static batch (prefill + greedy decode) or the
continuous-batching engine on a synthetic Poisson arrival trace.

    # static batch
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small \
        --reduced --batch 8 --prompt-len 64 --gen 16 --mesh 2x4 \
        --decode-mode exact

    # request-level engine, Poisson arrivals
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-small \
        --reduced --engine --requests 32 --rate 4 --batch 8 \
        --prompt-len 64 --gen 16 --mesh 2x4
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8,
                    help="static batch size / engine slot count")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--decode-mode", default="exact",
                    choices=("exact", "prism"))
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "jnp"),
                    help="kernel dispatch: auto = Pallas compiled on "
                         "TPU, jnp elsewhere; pallas forces the kernels "
                         "(interpret mode off-TPU); jnp forces the "
                         "oracle path")
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine over a Poisson trace")
    ap.add_argument("--requests", type=int, default=32,
                    help="[engine] number of requests in the trace")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="[engine] Poisson arrival rate, requests/s")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--gang", action="store_true",
                    help="[engine] static-batching admission (baseline)")
    ap.add_argument("--chunk-len", type=int, default=64,
                    help="[engine] prefill chunk size (clamped to the "
                         "prefill length)")
    ap.add_argument("--prefill-mode", default="packed",
                    choices=("packed", "chunked", "padded"),
                    help="[engine] token-packed unified ticks "
                         "(default), chunked prefill, or the legacy "
                         "pad-to-length admission flush")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="[engine, packed] tokens per packed tick "
                         "(default: slots + chunk-len); must be >= the "
                         "slot count")
    ap.add_argument("--no-paged", action="store_true",
                    help="[engine] dense slot-row cache instead of the "
                         "paged page-table pool")
    ap.add_argument("--page-tokens", type=int, default=None,
                    help="[engine, paged] page size in token positions "
                         "(default: derived, ~16-token spans)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="[engine, paged] physical pool pages (default: "
                         "memory parity with the dense rows)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="[engine, paged] disable shared-prefix COW "
                         "reuse (on by default in exact decode mode)")
    ap.add_argument("--offload", action="store_true",
                    help="[engine, paged] host KV offload tier: blocked "
                         "higher-priority arrivals preempt lower-priority "
                         "work (spill to host memory, restore on resume)")
    ap.add_argument("--priority", type=int, default=1, metavar="CLASSES",
                    help="[engine] priority classes in the synthetic "
                         "trace — each request draws uniform [0, CLASSES)"
                         " (higher = more urgent; 1 = plain FIFO)")
    ap.add_argument("--stream", action="store_true",
                    help="[engine] asyncio streaming front-end: tokens "
                         "stream per request while the double-buffered "
                         "loop overlaps host and device work "
                         "(docs/streaming.md)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="[engine, stream] disable double-buffered "
                         "dispatch (synchronous ticks; tokens still "
                         "stream) — the A/B baseline for the overlap win")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="[engine] seeded fault injection: run the "
                         "trace under FaultPlan.chaos(SEED) — store "
                         "put/get loss, page poisoning, admission "
                         "stalls, tick delays, shard loss — and audit "
                         "zero leaks after the drain")
    ap.add_argument("--shard-loss", type=int, default=None,
                    metavar="SHARD",
                    help="[engine] degraded-mesh drill: kill sequence "
                         "shard SHARD's KV mid-trace (scheduled "
                         "shard_loss fault) — in-flight requests serve "
                         "through the Segment-Means standby replicas, "
                         "then recover by deterministic re-prefill; "
                         "zero-leak audited like --chaos")
    ap.add_argument("--shard-loss-at", type=int, default=6, metavar="N",
                    help="[engine] shard_loss fires at the Nth "
                         "opportunity (engine tick with work; "
                         "default 6)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.protocol import PrismConfig
    from repro.models import transformer as T
    from repro.runtime.serve import (ServeHParams, make_prefill_step,
                                     make_serve_step)

    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((data, model), ("data", "model"))
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    if args.checkpoint:
        from repro.checkpoint.io import restore_checkpoint, latest_step
        step_n = latest_step(args.checkpoint)
        params = restore_checkpoint(args.checkpoint, step_n, params)
        print(f"[serve] restored step {step_n}")

    from repro.runtime.serve import seq_shards
    n_seq = seq_shards(mesh, args.batch)
    n = args.prompt_len - args.prompt_len % n_seq
    cap = n + args.gen + (-(n + args.gen)) % n_seq
    hp = ServeHParams(decode_mode=args.decode_mode, means_cr=args.cr,
                      backend=args.backend)
    prism = PrismConfig(
        P=model, cr=args.cr,
        mode="prism" if args.decode_mode == "prism" else "voltage")

    if args.engine:
        from repro.serving import (EngineConfig, FaultPlan, SamplingParams,
                                   ServingEngine)
        from repro.runtime.faults import FaultSpec
        faults = (FaultPlan.chaos(args.chaos)
                  if args.chaos is not None else None)
        if args.shard_loss is not None:
            spec = FaultSpec(at=(args.shard_loss_at,),
                             shard=args.shard_loss)
            faults = (FaultPlan(shard_loss=spec) if faults is None
                      else FaultPlan.chaos(args.chaos, shard_loss=spec))
        ecfg = EngineConfig(
            n_slots=args.batch, prefill_len=n, max_cache=cap, hp=hp,
            prism=prism, gang=args.gang, chunk_len=args.chunk_len,
            prefill_mode=args.prefill_mode,
            token_budget=args.token_budget,
            paged=not args.no_paged, page_tokens=args.page_tokens,
            n_pages=args.n_pages,
            prefix_cache=False if args.no_prefix_cache else None,
            offload=args.offload, faults=faults,
            max_restarts=8 if faults is not None else 3)
        eng = ServingEngine(cfg, mesh, params, ecfg)
        seng = None
        if args.stream:
            from repro.serving import StreamingEngine
            seng = StreamingEngine(eng, overlap=not args.no_overlap)
        rng = np.random.default_rng(0)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             size=args.requests))
        streams = {}
        for i in range(args.requests):
            plen = int(rng.integers(max(1, n // 2), n + 1))
            prompt = rng.integers(1, cfg.vocab_size, size=plen)
            kw = dict(max_new_tokens=args.gen,
                      sampling=SamplingParams(temperature=args.temperature,
                                              top_k=args.top_k, seed=i),
                      arrival=float(arrivals[i]),
                      priority=int(rng.integers(0, max(1, args.priority))))
            if seng is not None:
                rid, stream = seng.submit_stream(prompt, **kw)
                streams[rid] = stream
            else:
                eng.submit(prompt, **kw)
        mode = "gang (static)" if args.gang else "continuous"
        extras = (f", {args.priority} priority classes"
                  if args.priority > 1 else "")
        extras += ", host offload" if args.offload else ""
        extras += (f", chaos seed {args.chaos}"
                   if args.chaos is not None else "")
        extras += (f", shard {args.shard_loss} dies at tick "
                   f"{args.shard_loss_at}"
                   if args.shard_loss is not None else "")
        extras += (", streaming" + (" (overlap off)" if args.no_overlap
                                    else " (overlap)")
                   if args.stream else "")
        print(f"[engine] {args.requests} requests, Poisson rate "
              f"{args.rate}/s, {args.batch} slots, {mode} admission"
              f"{extras}")
        if seng is not None:
            import asyncio

            async def _drive():
                loop = asyncio.get_running_loop()
                got = {}

                async def consume(rid, stream):
                    toks = []
                    async for t in stream:
                        toks.append(t)
                    got[rid] = (toks, stream.finished)

                tasks = [asyncio.ensure_future(consume(rid, s))
                         for rid, s in streams.items()]
                while seng.has_work:
                    kind = await loop.run_in_executor(None, seng.step)
                    if kind == "idle":
                        await asyncio.sleep(0.002)
                seng.drain()
                seng._flush_streams()
                await asyncio.gather(*tasks)
                return got

            got = asyncio.run(_drive())
            fins = {}
            for toks, fin in got.values():
                fins[fin] = fins.get(fin, 0) + 1
            print(f"[stream] {len(got)} streams closed: "
                  + ", ".join(f"{k}={v}" for k, v in sorted(fins.items())))
            itl = [dt for ds in seng.itl_samples().values() for dt in ds]
            if itl:
                print(f"[stream] itl_p50_s {float(np.percentile(itl, 50)):.4f}"
                      f"  itl_p99_s {float(np.percentile(itl, 99)):.4f}")
        else:
            eng.run()
        for k, v in eng.stats.summary().items():
            print(f"[engine] {k:22s} {v:.3f}"
                  if isinstance(v, float) else f"[engine] {k:22s} {v}")
        if faults is not None:
            inj = eng._injector
            print(f"[chaos] injected {inj.stats()['injected']} over "
                  f"{inj.stats()['ops']} opportunities")
            done = len(eng.results())
            failed = eng.failed()
            print(f"[chaos] completed {done}/{args.requests}, "
                  f"failed {len(failed)} {sorted(failed.values())}")
            assert done + len(failed) == args.requests, (
                done, failed, args.requests)
            # zero-leak audits: page refcounts consistent, no slot
            # holds pages, store drained, every slot back in the pool
            kv = eng.kv_cache
            kv.check()
            assert not kv.slot_pages and not kv.slot_state
            if eng.kv_store is not None:
                assert len(eng.kv_store) == 0, eng.kv_store.stats()
            assert sorted(eng._sched.free_slots) == list(
                range(args.batch))
            print("[chaos] zero-leak audits OK")
            if args.shard_loss is not None:
                s = eng.stats
                rep = (eng._replica.stats()
                       if eng._replica is not None else {})
                print(f"[degraded] shard_lost {s.shard_lost} "
                      f"degraded_ticks {s.degraded_ticks} "
                      f"restarts {s.restarts} "
                      f"replica_captures {rep.get('captures', 0)}")
                assert s.shard_lost >= 1, "shard_loss never fired"
        return

    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, size=(args.batch, n)).astype(np.int32)

    # the prefill program captures its cache rows straight at decode
    # capacity (cap=...), so the old grow-to-capacity pad is gone
    prefill, lay_p, _, _ = make_prefill_step(
        cfg, mesh, params, prism, batch=args.batch, n=n, hp=hp, cap=cap)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    logits.block_until_ready()
    print(f"[serve] prefill {args.batch}x{n}: {time.time() - t0:.2f}s "
          f"({args.decode_mode} cache)")

    step, lay_d, _, _ = make_serve_step(
        cfg, mesh, params, batch=args.batch, cap=cap, prefill_len=n, hp=hp)
    assert lay_p == lay_d, (lay_p, lay_d)

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.time()
    for g in range(args.gen - 1):
        pos = jnp.full((args.batch,), n + g, jnp.int32)
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({1e3 * dt / max(1, args.gen - 1):.1f} ms/token)")
    gen = np.stack(out, axis=1)
    print("[serve] generated token ids (first 2 rows):")
    print(gen[:2])


if __name__ == "__main__":
    main()

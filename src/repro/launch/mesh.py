"""Production mesh definitions (TPU v5e).

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

The ``model`` axis carries PRISM's P: activations (and KV caches) are
sharded over it on the sequence dimension, and the per-block Segment-Means
exchange is an all-gather over it.  ``data`` carries batch + FSDP.  ``pod``
is pure data parallelism — PRISM's sequence exchange never crosses the
(slow) pod boundary, matching the paper's premise.

Functions, not module constants: importing this module must not touch jax
device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as _np
    n = int(_np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    # host-device stand-ins may exceed the mesh size (512 forced for the
    # dry-run; the single-pod mesh takes the first 256)
    assert len(devs) >= n, (len(devs), n)
    return jax.sharding.Mesh(_np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 4, data: int = 2):
    """Small mesh over host CPU devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))

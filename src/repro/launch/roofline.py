"""Roofline-term extraction from a compiled dry-run artifact.

TPU v5e constants (target hardware — this container only compiles):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

The three terms (per device, seconds):
    compute    = HLO_FLOPs / peak_flops
    memory     = HLO_bytes_accessed / hbm_bw
    collective = per-device collective link-bytes / ici_bw

``collective_bytes`` is parsed from the compiled HLO text: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we take the result shard shape and the replica-group size G and apply
the standard ring-algorithm byte counts:

    all-gather          result_bytes · (G-1)/G        (received)
    reduce-scatter      result_bytes · (G-1)           (operand streamed)
    all-reduce          2 · operand_bytes · (G-1)/G    (RS + AG phases)
    all-to-all          result_bytes · (G-1)/G
    collective-permute  result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes / s
ICI_BW = 50e9              # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUP_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link bytes by collective kind, parsed from HLO."""
    out = dict.fromkeys(_KINDS, 0)
    counts = dict.fromkeys(_KINDS, 0)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in line:
            continue
        shapes = _TUPLE_SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        bytes_ = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = 1
        gm = _GROUP_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm2 = _GROUP_V2_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        if kind == "all-gather":
            out[kind] += int(bytes_ * (g - 1) / max(g, 1))
        elif kind == "reduce-scatter":
            out[kind] += int(bytes_ * (g - 1))
        elif kind == "all-reduce":
            out[kind] += int(2 * bytes_ * (g - 1) / max(g, 1))
        elif kind == "all-to-all":
            out[kind] += int(bytes_ * (g - 1) / max(g, 1))
        else:
            out[kind] += int(bytes_)
        counts[kind] += 1
    total = sum(out.values())
    return dict(out, ops=counts, total=total)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    mode: str
    flops: float               # per device
    bytes_accessed: float      # per device
    coll_bytes: float          # per device
    model_flops: float         # 6·N_active·D global (train) / 2·N·D (infer)
    chips: int

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_frac(self):
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def row(self):
        return dict(asdict(self),
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective,
                    bottleneck=self.bottleneck,
                    useful=self.useful_flops_frac)


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference."""
    return (6.0 if kind == "train" else 2.0) * n_active_params * tokens

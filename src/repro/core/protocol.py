"""The PRISM per-block exchange protocol (paper §III, Fig. 1).

Position-wise partitioning (Alg. 1) splits the sequence into ``P``
contiguous partitions.  After every Transformer block each device compresses
its partition output into ``L`` segment means (Alg. 2) and all-gathers the
means; each device then augments its local partition with the received
means (Eq. 6), attends with the scaling-aware softmax (Eq. 13–15) under the
partition-aware mask (Eq. 17).

This module is the *protocol* — partition bookkeeping, augmentation,
repeat-count vectors, per-device masks, and communication accounting — in
host-side simulation form (a loop over P logical devices on one chip).  The
sharded runtime (`repro.sharding`, `repro.runtime`) executes the same math
under `shard_map`, with the all-gather over the ``model`` mesh axis; tests
assert the two paths agree.

Modes:
    'prism'       Segment-Means exchange, scaling-aware softmax (this paper)
    'voltage'     full-partition exchange, exact attention      (baseline [20])
    'duplicate'   Segment-Means exchange, duplicated rows       (Table II ablation)
    'prism_nodup' Segment-Means exchange, NO duplication (g=1)  (Table II 'No' column)
    'single'      no partitioning                               (no-partition row)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

import numpy as np
import jax.numpy as jnp

from .segment_means import (
    segment_means, segment_sizes, segment_bounds, duplicate_means,
    num_landmarks,
)
from .masks import visibility, exact_cols

MODES = ("prism", "voltage", "duplicate", "prism_nodup", "single")


@dataclass(frozen=True)
class PrismConfig:
    """Everything a device needs to know about the exchange."""
    P: int = 1                    # partitions == devices on the sequence axis
    cr: float = 1.0               # compression rate (Eq. 16); L = N/(CR*P)
    L: int | None = None          # explicit landmark count overrides cr
    mode: str = "prism"
    causal: bool = True
    prefix_len: int = 0           # prefix-LM (VLM image prefix)
    window: int | None = None     # sliding-window layers (gemma3 local)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.P < 1:
            raise ValueError("P >= 1 required")

    def landmarks(self, n: int) -> int:
        if self.L is not None:
            return self.L
        return num_landmarks(n, self.cr, self.P)

    def with_(self, **kw) -> "PrismConfig":
        return replace(self, **kw)


def partition_bounds(n: int, p: int) -> list[tuple[int, int]]:
    """Alg. 1: (start, size) per partition; last takes the remainder."""
    s, r = divmod(n, p)
    if s == 0:
        raise ValueError(f"cannot split N={n} into P={p} partitions")
    out, start = [], 0
    for i in range(p):
        size = s + (r if i == p - 1 else 0)
        out.append((start, size))
        start += size
    return out


def partition(x: jnp.ndarray, p: int, axis: int = -2) -> list[jnp.ndarray]:
    """Alg. 1 applied to an array along the sequence axis."""
    n = x.shape[axis]
    idx = [slice(None)] * x.ndim
    parts = []
    for start, size in partition_bounds(n, p):
        idx[axis] = slice(start, start + size)
        parts.append(x[tuple(idx)])
    return parts


@dataclass(frozen=True)
class DeviceView:
    """What device ``p`` sees for one block's attention."""
    p: int
    x_p: jnp.ndarray              # (..., N_p, D) local partition (queries)
    x_hat: jnp.ndarray            # (..., M, D)  augmented K/V source (Eq. 6)
    g: np.ndarray | None          # (M,) repeat counts; None => exact
    col_lo: np.ndarray            # (M,) global position ranges per column
    col_hi: np.ndarray
    row_pos: np.ndarray           # (N_p,) global positions of local rows

    def mask(self, cfg: PrismConfig) -> jnp.ndarray:
        return visibility(
            jnp.asarray(self.row_pos), jnp.asarray(self.col_lo),
            jnp.asarray(self.col_hi), causal=cfg.causal,
            prefix_len=cfg.prefix_len, window=cfg.window,
        )


def device_views(x: jnp.ndarray, cfg: PrismConfig) -> list[DeviceView]:
    """Build every device's augmented view of hidden states ``x (..., N, D)``.

    Column order per Eq. 6 / Eq. 17: local partition first, then the other
    partitions' summaries in ascending partition order (so the means of all
    *preceding* partitions occupy a contiguous visible span — Fig. 3c).
    """
    n = x.shape[-2]
    bounds = partition_bounds(n, cfg.P)
    parts = partition(x, cfg.P)

    if cfg.mode == "single" or cfg.P == 1:
        lo, hi = exact_cols(n)
        return [DeviceView(0, x, x, None, lo, hi, np.arange(n))]

    if cfg.mode == "voltage":
        lo, hi = exact_cols(n)
        views = []
        for p, (start, size) in enumerate(bounds):
            views.append(DeviceView(
                p, parts[p], x, None, lo, hi, np.arange(size) + start))
        return views

    # ---- prism / duplicate: compress each partition ----
    L = cfg.landmarks(n)
    z, sizes, zlo, zhi = [], [], [], []
    for p, (start, size) in enumerate(bounds):
        if L > size:
            raise ValueError(
                f"L={L} exceeds partition size {size}; lower cr or P")
        z.append(segment_means(parts[p], L))
        sizes.append(segment_sizes(size, L))
        lo, hi = segment_bounds(size, L, offset=start)
        zlo.append(lo)
        zhi.append(hi)

    views = []
    for p, (start, size) in enumerate(bounds):
        others = [q for q in range(cfg.P) if q != p]
        if cfg.mode == "duplicate":
            remote = [duplicate_means(z[q], bounds[q][1]) for q in others]
            g = None
            r_lo = [np.repeat(zlo[q], sizes[q]) for q in others]
            r_hi = [np.repeat(zhi[q], sizes[q]) for q in others]
        else:
            remote = [z[q] for q in others]
            if cfg.mode == "prism_nodup":        # Table II 'No' column
                g = np.ones(size + (cfg.P - 1) * L, np.int64)
            else:
                g = np.concatenate(
                    [np.ones(size, np.int64)] + [sizes[q] for q in others])
            r_lo = [zlo[q] for q in others]
            r_hi = [zhi[q] for q in others]
        x_hat = jnp.concatenate([parts[p]] + remote, axis=-2)
        loc_lo, loc_hi = exact_cols(size, offset=start)
        views.append(DeviceView(
            p, parts[p], x_hat, g,
            np.concatenate([loc_lo] + r_lo),
            np.concatenate([loc_hi] + r_hi),
            np.arange(size) + start,
        ))
    return views


def comm_elements_per_device_per_layer(n: int, d: int, cfg: PrismConfig) -> float:
    """Elements each device transmits per Transformer block (paper §IV-C)."""
    if cfg.P == 1 or cfg.mode == "single":
        return 0.0
    if cfg.mode == "voltage":
        return (cfg.P - 1) * n * d / cfg.P
    L = cfg.landmarks(n)
    return float((cfg.P - 1) * L * d)


def tensor_parallel_comm(n: int, d: int, p: int) -> float:
    """Megatron-style TP per-device per-layer traffic: 4(P-1)ND/P (§II-B2)."""
    return 4 * (p - 1) * n * d / p


def comm_speedup(n: int, d: int, cfg: PrismConfig) -> float:
    """Paper's 'Comm. Speed-up %' = 1 - prism/voltage."""
    volt = comm_elements_per_device_per_layer(n, d, cfg.with_(mode="voltage"))
    ours = comm_elements_per_device_per_layer(n, d, cfg)
    return 100.0 * (1.0 - ours / volt) if volt else 0.0

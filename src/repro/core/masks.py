"""Partition-aware attention masks (paper §IV-D, Eq. 17), generalized.

Every column of the augmented K/V matrix ``X̂_p = [X_p ; Z_q …]`` covers a
*range* of global token positions: an exact local token covers ``[i, i]``;
a segment mean covers ``[lo, hi]`` — the first/last global position of the
tokens it aggregates.  A single rule then expresses all the mask variants
PRISM needs:

    visible(row i, col [lo, hi]) =
        (not causal)            OR  hi <= pos(i)          # strictly past/self
        OR hi < prefix_len                                 # prefix-LM bidirectional prefix
    AND (window is None OR lo > pos(i) - window)           # sliding window

With exact columns (lo == hi == j) and causal=True this reduces to the
standard lower-triangular mask; for a remote *preceding* partition's means
``hi < start_p`` so they are fully visible, and for a *following* partition
``lo > pos(i)`` so they are fully masked — exactly Eq. 17.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps bf16 finite


def visibility(
    row_pos: jnp.ndarray,       # (Nq,)  global positions of query rows
    col_lo: jnp.ndarray,        # (M,)   first global position covered by col
    col_hi: jnp.ndarray,        # (M,)   last  global position covered by col
    *,
    causal: bool,
    prefix_len: int = 0,
    window: int | None = None,
) -> jnp.ndarray:
    """Boolean (Nq, M) mask; True = attend."""
    r = row_pos[:, None]
    if causal:
        vis = col_hi[None, :] <= r
        if prefix_len > 0:
            vis = vis | (col_hi[None, :] < prefix_len)
    else:
        vis = jnp.ones((row_pos.shape[0], col_lo.shape[0]), dtype=bool)
    if window is not None:
        vis = vis & (col_lo[None, :] > r - window)
    return vis


def visibility_np(row_pos, col_lo, col_hi, *, causal: bool,
                  prefix_len: int = 0, window=None) -> np.ndarray:
    """Pure-numpy visibility — for STATIC masks built at trace time
    (SimulatedContext): jnp ops on constants still produce tracers inside
    jit, so static mask construction must stay in numpy."""
    r = np.asarray(row_pos)[:, None]
    lo = np.asarray(col_lo)[None, :]
    hi = np.asarray(col_hi)[None, :]
    if causal:
        vis = hi <= r
        if prefix_len > 0:
            vis = vis | (hi < prefix_len)
    else:
        vis = np.ones((r.shape[0], lo.shape[1]), bool)
    if window is not None:
        vis = vis & (lo > r - window)
    return vis


def partition_causal_mask(
    n_p: int,
    partition_start: int,
    col_lo: np.ndarray,
    col_hi: np.ndarray,
    *,
    prefix_len: int = 0,
    window: int | None = None,
    causal: bool = True,
) -> jnp.ndarray:
    """Eq. 17 mask for one device: rows are the local partition's tokens
    (global positions ``partition_start .. partition_start + n_p - 1``),
    columns described by (lo, hi) position ranges."""
    row_pos = jnp.arange(n_p) + partition_start
    return visibility(
        row_pos, jnp.asarray(col_lo), jnp.asarray(col_hi),
        causal=causal, prefix_len=prefix_len, window=window,
    )


def mask_to_bias(mask: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Boolean mask -> additive bias (0 / NEG_INF)."""
    return jnp.where(mask, jnp.zeros((), dtype), jnp.full((), NEG_INF, dtype))


def exact_cols(n: int, offset: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) ranges for n exact (uncompressed) columns."""
    pos = np.arange(n) + offset
    return pos, pos

"""Segment Means compression (paper §IV-B, Algorithm 2).

A partition ``X_p ∈ R^{..., N_p, D}`` is divided into ``L`` contiguous,
non-overlapping segments: the first ``L-1`` of size ``s = floor(N_p / L)``
and the last of size ``s + (N_p mod L)``.  The column-wise mean of each
segment is its *segment mean*; the stacked means ``Z_p ∈ R^{..., L, D}``
are what PRISM exchanges between devices instead of the full partition.

All shapes are static at trace time, so the ragged last segment is handled
with two static slices — no dynamic shapes, no gather.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def segment_sizes(n_p: int, L: int) -> np.ndarray:
    """Per-segment token counts ``n_l`` (paper Eq. 8): [s]*(L-1) + [s+r]."""
    if not 1 <= L <= n_p:
        raise ValueError(f"need 1 <= L <= N_p, got L={L}, N_p={n_p}")
    s, r = divmod(n_p, L)
    sizes = np.full(L, s, dtype=np.int64)
    sizes[-1] += r
    return sizes


def segment_bounds(n_p: int, L: int, offset: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) inclusive global-position bounds of each segment's tokens.

    ``offset`` shifts into global sequence coordinates (partition start).
    Used by the partition-aware mask: a mean column is causally visible to
    a query at global position ``i`` iff ``hi <= i``.
    """
    sizes = segment_sizes(n_p, L)
    ends = np.cumsum(sizes)
    starts = ends - sizes
    return starts + offset, ends - 1 + offset


def segment_fill_counts(lo, hi, filled) -> jnp.ndarray:
    """Per-segment count of *real* tokens once positions ``[0, filled)``
    have been laid down.  ``lo``/``hi`` are the static inclusive
    position bounds of each segment column (``segment_bounds``, or the
    serving layout's global means grid); ``filled`` is a traced fill
    level with any leading batch shape — the segment axis is appended
    last.

    Returns ``clip(min(filled, hi+1) - lo, 0, n_l)`` — the repeat
    counts ``g`` a scaling-aware softmax must use so a mean over a
    partially-filled (or padded) segment never weighs columns that hold
    no real token.  Chunked prefill recomputes this every chunk; after
    the final chunk it is exactly the per-request real-column count."""
    lo = jnp.asarray(lo)
    hi = jnp.asarray(hi)
    filled = jnp.asarray(filled)[..., None]
    return jnp.clip(jnp.minimum(filled, hi + 1) - lo, 0,
                    None).astype(jnp.float32)


def segment_means(x: jnp.ndarray, L: int) -> jnp.ndarray:
    """Compress ``x (..., N_p, D)`` to ``(..., L, D)`` segment means."""
    n_p = x.shape[-2]
    if not 1 <= L <= n_p:
        raise ValueError(f"need 1 <= L <= N_p, got L={L}, N_p={n_p}")
    s = n_p // L
    if L == 1:
        return x.mean(axis=-2, keepdims=True)
    head = x[..., : s * (L - 1), :]
    head = head.reshape(*x.shape[:-2], L - 1, s, x.shape[-1]).mean(axis=-2)
    tail = x[..., s * (L - 1):, :].mean(axis=-2, keepdims=True)
    return jnp.concatenate([head, tail], axis=-2)


def duplicate_means(z: jnp.ndarray, n_p: int) -> jnp.ndarray:
    """Expand means back to ``(..., N_p, D)`` by per-segment repetition
    (paper Eq. 11, ``Y_p``).  Only used by the reference/oracle path and
    the Table-II ablation — PRISM proper never materializes this."""
    L = z.shape[-2]
    sizes = segment_sizes(n_p, L)
    idx = np.repeat(np.arange(L), sizes)
    return jnp.take(z, jnp.asarray(idx), axis=-2)


def num_landmarks(n: int, cr: float, p: int) -> int:
    """L = floor(N / (CR * P)) (paper Eq. 16), clamped to >= 1."""
    return max(1, int(n // (cr * p)))


def compression_rate(n: int, L: int, p: int) -> float:
    """The effective CR achieved by a given L (inverse of Eq. 16)."""
    return n / (L * p)

"""PRISM attention: scaling-aware softmax over compressed K/V (paper §IV-C).

The restructured attention (Eq. 13–15) never materializes duplicated
segment-mean rows.  Given per-column repeat counts ``g`` (1 for exact local
tokens, ``n_l`` for a mean that summarizes ``n_l`` tokens):

    Ψ = exp(Q K̂ᵀ / √d)            (Eq. 13)
    E = Ψ ⊙ g                      (Eq. 14, column-wise)
    A = rownorm(E) · V̂            (Eq. 15)

which equals ordinary softmax attention over the row-duplicated K/V
(exponentiation/multiplication associativity).  Numerically we fold the
scaling into the logits as ``+ log g`` and run a standard stable softmax —
the identity ``g · e^x = e^{x + log g}`` — which is also what the Pallas
kernel streams.

All functions take multi-head tensors with GQA layout:
    q: (B, Nq, Hq, hd)    k, v: (B, M, Hkv, hd)     Hq % Hkv == 0
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .masks import NEG_INF


def _gqa_logits(q: jnp.ndarray, k: jnp.ndarray, scale: float) -> jnp.ndarray:
    """(B, Hq, Nq, M) attention logits with KV-head grouping."""
    b, nq, hq, hd = q.shape
    _, m, hkv, _ = k.shape
    assert hq % hkv == 0, f"Hq={hq} not a multiple of Hkv={hkv}"
    grp = hq // hkv
    qg = q.reshape(b, nq, hkv, grp, hd)
    logits = jnp.einsum("bnkgh,bmkh->bkgnm", qg, k) * scale
    return logits.reshape(b, hq, nq, m)


def _gqa_output(weights: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """(B, Hq, Nq, M) @ (B, M, Hkv, hd) -> (B, Nq, Hq, hd)."""
    b, hq, nq, m = weights.shape
    hkv = v.shape[2]
    grp = hq // hkv
    wg = weights.reshape(b, hkv, grp, nq, m)
    out = jnp.einsum("bkgnm,bmkh->bnkgh", wg, v)
    return out.reshape(b, nq, hq, v.shape[-1])


def log_repeats(g: jnp.ndarray) -> jnp.ndarray:
    """Repeat counts -> additive logit bias: log g, with g = 0 columns
    sent to NEG_INF (dead — own-shard means, padding, not-yet-covered
    segments).  The Eq. 14 scaling in the form every implementation
    (jnp, streamed, Pallas) folds into its logits."""
    g = g.astype(jnp.float32)
    return jnp.where(g > 0, jnp.log(jnp.maximum(g, 1e-30)), NEG_INF)


def scaling_softmax(
    logits: jnp.ndarray,          # (..., M)
    log_g: jnp.ndarray | None,    # (M,) or broadcastable; None => all-ones g
    mask: jnp.ndarray | None,     # bool (..., M) or (Nq, M); True = attend
) -> jnp.ndarray:
    """Stable softmax of ``logits + log g`` with masking (Eq. 14 rewrite)."""
    x = logits.astype(jnp.float32)
    if log_g is not None:
        x = x + log_g.astype(jnp.float32)
    if mask is not None:
        x = jnp.where(mask, x, NEG_INF)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    if mask is not None:
        # fully-masked rows: max-subtraction turns NEG_INF-NEG_INF into 0,
        # so re-zero masked entries -> such rows yield 0, not uniform
        e = jnp.where(mask, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def prism_attention(
    q: jnp.ndarray,               # (B, Nq, Hq, hd)  local-partition queries
    k_hat: jnp.ndarray,           # (B, M, Hkv, hd)  augmented (local + means)
    v_hat: jnp.ndarray,           # (B, M, Hkv, hd)
    g: jnp.ndarray | None = None, # (M,) repeat counts; None = exact attention
    mask: jnp.ndarray | None = None,  # bool (Nq, M) or (B, 1|Hq, Nq, M)
    *,
    scale: float | None = None,
    block: int = 0,               # >0: stream K/V in blocks (flash-style)
) -> jnp.ndarray:
    """Scaling-aware attention (Eq. 15).  With g=None and an ordinary causal
    mask this is exact softmax attention — the single-device baseline.

    ``block``: stream the K/V columns in blocks with a running
    max/normalizer (the XLA-level analogue of the Pallas flash kernel) —
    the (B,Hq,Nq,M) logits tensor is never materialized, cutting the
    training/prefill HBM peak (§Perf H3).  Falls back to the dense path
    for small M or batched masks."""
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    if (block and k_hat.shape[1] > 2 * block
            and (mask is None or mask.ndim == 2)):
        return _streamed_attention(q, k_hat, v_hat, g, mask,
                                   scale=scale, block=block)
    logits = _gqa_logits(q, k_hat, scale)
    log_g = None if g is None else jnp.log(g.astype(jnp.float32))
    if mask is not None and mask.ndim == 2:
        mask = mask[None, None]
    w = scaling_softmax(logits, log_g, mask)
    return _gqa_output(w.astype(v_hat.dtype), v_hat)


def _streamed_attention(q, k_hat, v_hat, g, mask, *, scale, block):
    """lax.scan over K/V column blocks with running (m, l, acc) — the
    Eq. 13-15 softmax in streaming form (cf. kernels/prism_attention.py,
    which is the same algorithm as a Pallas VMEM kernel)."""
    b, nq, hq, hd = q.shape
    m_cols = k_hat.shape[1]
    pad = (-m_cols) % block
    if pad:
        widths = [(0, 0)] * 4
        widths[1] = (0, pad)
        k_hat = jnp.pad(k_hat, widths)
        v_hat = jnp.pad(v_hat, widths)
        if g is None:
            g = jnp.ones((m_cols,), jnp.float32)
        g = jnp.pad(g.astype(jnp.float32), (0, pad))      # pad g=0 -> dead
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    mt = k_hat.shape[1]
    nb = mt // block
    log_g = (jnp.where(g > 0, jnp.log(jnp.maximum(
        g.astype(jnp.float32), 1e-30)), NEG_INF)
        if g is not None else jnp.zeros((mt,), jnp.float32))
    if pad and g is None:
        dead = jnp.arange(mt) >= m_cols
        log_g = jnp.where(dead, NEG_INF, log_g)

    kb = k_hat.reshape(b, nb, block, *k_hat.shape[2:]).swapaxes(0, 1)
    vb = v_hat.reshape(b, nb, block, *v_hat.shape[2:]).swapaxes(0, 1)
    lgb = log_g.reshape(nb, block)
    maskb = (mask.reshape(nq, nb, block).swapaxes(0, 1)
             if mask is not None else None)

    m0 = jnp.full((b, hq, nq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, nq, 1), jnp.float32)
    a0 = jnp.zeros((b, nq, hq, hd), jnp.float32)

    def body(carry, xs):
        m_run, l_run, acc = carry
        if maskb is None:
            k_c, v_c, lg_c = xs
            msk = None
        else:
            k_c, v_c, lg_c, msk = xs
        s = _gqa_logits(q, k_c, scale).astype(jnp.float32)
        s = s + lg_c[None, None, None, :]
        if msk is not None:
            s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(-1, keepdims=True))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(s > NEG_INF / 2, p, 0.0)
        l_new = l_run * corr + p.sum(-1, keepdims=True)
        part = _gqa_output(p.astype(v_c.dtype), v_c).astype(jnp.float32)
        acc = acc * corr[:, :, :, 0].swapaxes(1, 2)[..., None] + part
        return (m_new, l_new, acc), None

    xs = (kb, vb, lgb) if maskb is None else (kb, vb, lgb, maskb)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    denom = jnp.maximum(l_f[:, :, :, 0].swapaxes(1, 2)[..., None], 1e-30)
    return (acc / denom).astype(v_hat.dtype)


def exact_attention(q, k, v, mask=None, *, scale=None):
    """Plain softmax attention (no compression) — Voltage / no-partition."""
    return prism_attention(q, k, v, g=None, mask=mask, scale=scale)

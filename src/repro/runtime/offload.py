"""Host-memory KV offload tier.

A :class:`KVStore` holds the spilled cache footprint of preempted (or
suspended) requests: the raw content of every device page a slot held,
plus — in prism mode — the request's Segment-Means state row
(``kz/vz/gz/zsum``).  Spilling is ONE device→host gather per request
(``KVCache.spill``); restoring re-enters through the normal page-aware
admission path (``plan_restore`` → ``reserve`` → ``bind`` →
``KVCache.restore``), so a restore is just an admit whose covered-token
count comes from the store instead of the prefix cache.

The store is a plain LRU keyed by request id.  Capacity is optional and
byte-denominated; when the payload is host-less (``KVCache`` built with
``storage=None``, as the scheduler-level tests do) the page count stands
in for bytes.  Entries that do not fit are *dropped* — callers must
treat a missing entry as host-memory pressure and fall back to
re-prefill (see ``ServingEngine._restore_gate``), never as an error that
can corrupt a neighbour slot.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any


def _tree_bytes(payload) -> int:
    """Total host bytes of a device_get'd pytree of numpy arrays."""
    import jax

    return int(sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(payload)))


@dataclass
class SpilledEntry:
    """One preempted request's cache footprint, resident on the host.

    ``payload`` mirrors the cache storage structure ({"scan": [...],
    "tail": [...]}) but holds only this request's slice: its pages
    gathered along the page axis and, in prism mode, its state row.
    ``payload is None`` in host-only bookkeeping mode (no device
    storage attached to the KVCache).
    """

    key: Any
    n_pages: int
    tokens: int            # covered-token count for the restore plan
    payload: Any
    nbytes: int


class KVStore:
    """LRU host store for spilled KV pages + prism state.

    ``capacity_bytes=None`` means unbounded.  ``capacity_bytes=0`` drops
    every put — the fault-injection configuration the restore-failure
    tests use to simulate total host-memory pressure.
    """

    def __init__(self, capacity_bytes: int | None = None, *,
                 injector=None):
        self.capacity_bytes = capacity_bytes
        self._entries: OrderedDict[Any, SpilledEntry] = OrderedDict()
        self.bytes_used = 0
        self.puts = 0
        self.drops = 0          # puts rejected (entry > capacity / fault)
        self.evictions = 0      # LRU entries pushed out by later puts
        self.hits = 0           # pops that found their entry
        self.misses = 0         # pops/peeks that did not
        self.get_retries = 0    # transient read losses retried away
        # seeded chaos hook (runtime/faults.py): a fired
        # ``store_put_loss`` drops the put, a fired ``store_get_loss``
        # loses an existing entry at read time — both surface to the
        # engine as ordinary restore misses
        self._injector = injector

    def _lost(self, kind: str) -> bool:
        return self._injector is not None and self._injector.fire(kind)

    # -- write side ----------------------------------------------------
    def put(self, key, n_pages: int, payload, *, tokens: int = 0) -> bool:
        """Store a spilled entry; returns False when it was dropped."""
        nbytes = _tree_bytes(payload) if payload is not None else int(n_pages)
        if key in self._entries:
            self.drop(key)
        self.puts += 1
        if self._lost("store_put_loss"):
            self.drops += 1
            return False
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            self.drops += 1
            return False
        self._entries[key] = SpilledEntry(key=key, n_pages=int(n_pages),
                                          tokens=int(tokens),
                                          payload=payload, nbytes=nbytes)
        self.bytes_used += nbytes
        while (self.capacity_bytes is not None
               and self.bytes_used > self.capacity_bytes):
            _, old = self._entries.popitem(last=False)   # LRU first
            self.bytes_used -= old.nbytes
            self.evictions += 1
        return True

    # -- read side -----------------------------------------------------
    def peek(self, key) -> SpilledEntry | None:
        """Look up without removing (used by ``plan_restore``)."""
        ent = self._entries.get(key)
        if ent is None:
            self.misses += 1
            return None
        if self._lost("store_get_loss"):
            self.drop(key)              # torn at read time: entry gone
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        return ent

    def pop(self, key) -> SpilledEntry | None:
        """Remove and return the entry, or None if it was lost."""
        ent = self._entries.pop(key, None)
        if ent is None:
            self.misses += 1
            return None
        if self._lost("store_get_loss"):
            self.bytes_used -= ent.nbytes
            self.misses += 1
            return None
        self.bytes_used -= ent.nbytes
        self.hits += 1
        return ent

    def get(self, key, *, retries: int = 0, backoff_s: float = 0.0,
            consume: bool = False) -> SpilledEntry | None:
        """Bounded retry-with-backoff read — the restore path's front
        door.  An injected ``store_get_loss`` models a TRANSIENT torn
        read, not necessarily permanent loss, so a loss on a non-final
        attempt RETAINS the entry and tries again (each attempt draws
        its own injector opportunity; ``backoff_s`` > 0 sleeps
        ``backoff_s * 2**attempt`` between attempts — the engine passes
        0 under logical clocks).  A loss on the final attempt keeps the
        old torn-read semantics: the entry is dropped and the caller
        downgrades to re-prefill.  ``retries=0`` is exactly ``peek``
        (or ``pop`` with ``consume=True``)."""
        for attempt in range(retries + 1):
            ent = self._entries.get(key)
            if ent is None:
                self.misses += 1
                return None
            if not self._lost("store_get_loss"):
                if consume:
                    self._entries.pop(key)
                    self.bytes_used -= ent.nbytes
                    self.hits += 1
                else:
                    self._entries.move_to_end(key)
                return ent
            if attempt < retries:
                self.get_retries += 1
                if backoff_s > 0.0:
                    time.sleep(backoff_s * (2 ** attempt))
        self.drop(key)                  # torn on the last attempt: gone
        self.misses += 1
        return None

    def drop(self, key) -> None:
        """Silently discard an entry (cancelled request, fault inject)."""
        ent = self._entries.pop(key, None)
        if ent is not None:
            self.bytes_used -= ent.nbytes

    # -- snapshot / restore --------------------------------------------
    def entries(self) -> list:
        """The live entries in LRU order (oldest first) — what an
        engine snapshot journals.  The SpilledEntry objects are shared,
        not copied; callers that persist them must deepcopy."""
        return list(self._entries.values())

    def adopt(self, entries) -> None:
        """Re-insert journalled entries verbatim (engine restore).
        Bypasses the counters AND the fault injector: restoring a
        snapshot replays state, it is not a new injection
        opportunity."""
        for ent in entries:
            old = self._entries.pop(ent.key, None)
            if old is not None:
                self.bytes_used -= old.nbytes
            self._entries[ent.key] = ent
            self.bytes_used += ent.nbytes

    # -- introspection -------------------------------------------------
    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> dict:
        return {"entries": len(self._entries),
                "bytes_used": self.bytes_used,
                "capacity_bytes": self.capacity_bytes,
                "puts": self.puts, "drops": self.drops,
                "evictions": self.evictions,
                "hits": self.hits, "misses": self.misses,
                "get_retries": self.get_retries}

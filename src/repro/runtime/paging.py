"""Paged KV cache: host-side page table, prefix reuse, and the unified
``KVCache`` lifecycle object.

The serving cache used to be a dense ``(n_slots, max_cache)`` rowset:
every request owned one fixed row, context length was capped by the
row, admission paid an ``insert_cache_row`` splice, and identical
system prompts were prefillled once per request.  This module replaces
that with a vLLM/levanter-style **block table**:

  * the device pool holds ``n_pages`` physical pages; a page is a gang
    of ``page_cols`` cache columns *on every sequence shard* (so one
    page covers ``page_cols * n_seq`` consecutive token positions under
    the round-robin placement, and the pool leaf is
    ``(n_pages, page_cols * n_seq, Hkv, hd)`` sharded over the sequence
    axes exactly like the old rows);
  * ``PageTable`` is the host-side allocator: free list, per-page
    refcounts, O(1) alloc/free — pure bookkeeping, no device work;
  * a request's "row" is a **page list**: logical page slot ``j`` of
    its virtual ``cap_l``-column row is backed by physical page
    ``pages[j]``.  The step programs in ``runtime.serve`` receive the
    per-slot page map ``(n_slots, pages_per_row)`` each tick and gather
    / scatter through one extra level of indirection;
  * **prefix caching**: completed prompts register their full pages
    under a rolling token hash; a new request whose prompt starts with
    a registered prefix maps those pages copy-on-write (refcount++) and
    skips prefilling the covered tokens entirely.  Shared pages are
    never written — writes only target positions past the covered
    boundary, which live in private pages by construction — and
    ``KVCache.fork_cow`` / ``ensure_writable`` copy a page out to a
    private one if a write would ever land in a shared page (the
    safety valve for future preemption/offload policies);
  * in ``prism`` decode mode the Segment-Means running state
    (kz/vz/gz/zsum) rides in its own **state-page pool**
    ``(n_state_pages, m, ...)``: each active request holds one state
    page (allocated/freed with its KV pages, addressed through the
    per-slot ``state_map``), so ROADMAP's KV-offload tier can spill and
    restore a request's *entire* cache footprint — raw KV pages plus
    compression state — through one indirection layer.

``KVCache`` is the single construction path for BOTH cache layouts:
``paging=None`` wraps the legacy dense rowset (kept as the oracle the
equivalence tests compare against) and absorbs the old free functions
(``insert_cache_row``/``grow_cache``/``reset_cache_row`` are now
deprecated shims over the ``insert_row``/``grow_from``/``reset_row``
methods); ``paging=PagedLayout(...)`` wraps the pool + ``PageTable``
with the ``alloc / append / fork_cow / free`` lifecycle the engine
drives.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import numpy as np
from jax import lax


#: page id meaning "no page mapped" in device page maps
NO_PAGE = -1


# --------------------------------------------------------------------------
# dense-rowset primitives (the former serve.py free functions)
# --------------------------------------------------------------------------

def splice_row(dst, src, src_row, dst_row):
    """Copy batch row ``src_row`` of cache ``src`` into row ``dst_row``
    of ``dst`` — a batch-dim ``dynamic_update_slice`` on every leaf.
    Stacked 'scan' leaves are (n_units, B, ...) — batch axis 1; 'tail'
    leaves are (B, ...) — batch axis 0."""
    def one(d, s, batch_axis):
        row = lax.dynamic_slice_in_dim(s, src_row, 1, axis=batch_axis)
        return lax.dynamic_update_slice_in_dim(
            d, row.astype(d.dtype), dst_row, axis=batch_axis)
    return {"scan": [jax.tree.map(lambda d, s: one(d, s, 1), dc, sc)
                     for dc, sc in zip(dst["scan"], src["scan"])],
            "tail": [jax.tree.map(lambda d, s: one(d, s, 0), dc, sc)
                     for dc, sc in zip(dst["tail"], src["tail"])]}


def grow_rows(cache, lay_from, lay_to):
    """Pad a prefill cache (cap == prefill_len) out to a larger decode
    capacity; only the sequence-sharded k/v leaves grow (per-shard
    interleaved pad)."""
    pad = lay_to.cap_l - lay_from.cap_l
    if pad == 0:
        return cache

    def fix(d):
        import jax.numpy as jnp
        out = {}
        for key, v in d.items():
            sd = v.ndim - 3
            if key in ("k", "v") and v.shape[sd] == lay_from.cap:
                lead = v.shape[:sd]
                v = v.reshape(*lead, lay_from.n_seq, lay_from.cap_l,
                              *v.shape[sd + 1:])
                widths = [(0, 0)] * v.ndim
                widths[sd + 1] = (0, pad)
                v = jnp.pad(v, widths)
                v = v.reshape(*lead, lay_to.cap, *v.shape[sd + 2:])
            out[key] = v
        return out
    return {"scan": [fix(c) for c in cache["scan"]],
            "tail": [fix(c) for c in cache["tail"]]}


def zero_row(cache, row):
    """Zero one batch row of a dense decode cache."""
    import jax.numpy as jnp

    def one_tree(tree, batch_axis):
        def fix(c):
            sh = list(c.shape)
            sh[batch_axis] = 1
            return lax.dynamic_update_slice_in_dim(
                c, jnp.zeros(sh, c.dtype), row, axis=batch_axis)
        return jax.tree.map(fix, tree)
    return {"scan": [one_tree(t, 1) for t in cache["scan"]],
            "tail": [one_tree(t, 0) for t in cache["tail"]]}


# --------------------------------------------------------------------------
# paged layout + page table
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PagedLayout:
    """Static shape of the paged pool, derived once per engine.

    A page holds ``page_cols`` per-shard cache columns on EVERY
    sequence shard, i.e. ``span = page_cols * n_seq`` consecutive token
    positions under the round-robin paged placement (exact mode).  The
    prism paged placement keeps the prefill-aligned column map (the
    Segment-Means shard ownership needs contiguous per-shard position
    blocks), where a page gang still holds ``page_cols`` columns per
    shard — prefix sharing is disabled there (a partial page set does
    not cover a position prefix)."""
    page_cols: int                    # per-shard columns per page
    n_seq: int                        # sequence shards (gang width)
    pages_per_row: int                # logical page slots per request
    n_pages: int                      # physical pages in the pool
    n_state_pages: int = 0            # prism means-state pool rows

    @property
    def span(self) -> int:            # tokens covered per page
        return self.page_cols * self.n_seq

    @property
    def pool_cap(self) -> int:        # global pool columns (dim 1)
        return self.page_cols * self.n_seq


def make_paged_layout(lay, *, page_tokens: int, n_pages: int | None,
                      n_slots: int, n_state_pages: int | None = None
                      ) -> PagedLayout:
    """Derive the pool shape from a ``ServeLayout``.  ``page_tokens``
    is the page size in token positions; it must be a multiple of the
    sequence-shard count and the resulting per-shard ``page_cols`` must
    divide both the prefill region and the full row (so chunk prior
    reads and row gathers stay static slices of whole pages)."""
    if page_tokens % lay.n_seq:
        raise ValueError(
            f"page_tokens {page_tokens} not a multiple of the "
            f"sequence-shard count {lay.n_seq}")
    pc = page_tokens // lay.n_seq
    if lay.n_loc0 % pc or lay.cap_l % pc:
        raise ValueError(
            f"page_cols {pc} must divide the per-shard prefill region "
            f"{lay.n_loc0} and capacity {lay.cap_l}")
    ppr = lay.cap_l // pc
    if n_pages is None:
        n_pages = n_slots * ppr       # memory parity with the dense rows
    if n_state_pages is None:
        n_state_pages = n_slots
    return PagedLayout(page_cols=pc, n_seq=lay.n_seq, pages_per_row=ppr,
                       n_pages=int(n_pages),
                       n_state_pages=int(n_state_pages))


class PageTable:
    """Host-side free-list page allocator with per-page refcounts.

    Pure bookkeeping — no device arrays.  A page is either on the free
    list (refcount 0) or owned by one or more holders (a request's page
    list and/or a prefix-cache entry), each holding exactly one
    refcount.  ``check()`` asserts the invariant; the churn tests drive
    admit/evict/requeue loops through it."""

    def __init__(self, n_pages: int):
        assert n_pages >= 1
        self.n_pages = n_pages
        self.refs = np.zeros(n_pages, np.int32)
        self._free: list = list(range(n_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int) -> list | None:
        """Take ``n`` fresh pages (refcount 1 each) or None if the free
        list cannot cover them — never a partial grant."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.refs[pages] += 1
        return pages

    def share(self, pages) -> None:
        """Add one reference to each (already-allocated) page — the
        copy-on-write map of a shared prefix into a new request."""
        pages = list(pages)
        if np.any(self.refs[pages] <= 0):
            raise ValueError(f"share of unallocated page in {pages}")
        self.refs[pages] += 1

    def free(self, pages) -> None:
        """Drop one reference per page; pages reaching refcount 0 go
        back on the free list."""
        for p in pages:
            if self.refs[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self.refs[p] -= 1
            if self.refs[p] == 0:
                self._free.append(p)

    def check(self) -> None:
        """Invariants: refcounts non-negative, free list is exactly the
        zero-ref pages, no duplicates."""
        free = sorted(self._free)
        assert len(set(free)) == len(free), "duplicate free-list entry"
        zero = sorted(np.nonzero(self.refs == 0)[0].tolist())
        assert free == zero, (free, zero)
        assert np.all(self.refs >= 0)


# --------------------------------------------------------------------------
# prefix cache
# --------------------------------------------------------------------------

@dataclass
class _PrefixEntry:
    key: tuple
    pages: tuple                       # physical ids, one per full span
    tokens: int                        # positions covered (= len(pages)*span)
    last_used: int = 0


class PrefixCache:
    """Token-hash → shared-page-prefix map (vLLM-style block hashing).

    A finished request registers one entry per full-page prefix level
    of its prompt: level ``k`` maps ``hash(prompt[:k*span])`` to its
    first ``k`` physical pages (each entry holds one refcount per
    page, so registered pages survive the owner's eviction).  Lookup
    walks levels longest-first; a hit maps the entry's pages
    copy-on-write into the new request.  ``reclaim`` drops LRU entries
    to refill the free list when admission runs out of pages — the
    prefix cache is a cache, never a reservation."""

    def __init__(self, table: PageTable):
        self.table = table
        self.entries: dict = {}        # key -> _PrefixEntry
        self.hits = 0
        self.misses = 0
        self._tick = 0

    @staticmethod
    def _key(prompt, n_tokens: int) -> tuple:
        return tuple(prompt[:n_tokens])

    def register(self, prompt, pages, span: int) -> int:
        """Register every full-page prefix level of ``prompt`` whose
        pages hold prompt tokens only.  Returns entries added."""
        k_reg = min(len(prompt) // span, len(pages))
        added = 0
        for k in range(1, k_reg + 1):
            key = self._key(prompt, k * span)
            ent = self.entries.get(key)
            self._tick += 1
            if ent is not None:
                ent.last_used = self._tick
                continue
            share = list(pages[:k])
            self.table.share(share)
            self.entries[key] = _PrefixEntry(
                key=key, pages=tuple(share), tokens=k * span,
                last_used=self._tick)
            added += 1
        return added

    def lookup(self, prompt, span: int) -> _PrefixEntry | None:
        """Longest registered full-page prefix STRICTLY shorter than
        the prompt (the rewind re-feeds the last prompt token, so the
        page holding position ``len(prompt) - 1`` must stay private)."""
        k_max = (len(prompt) - 1) // span
        for k in range(k_max, 0, -1):
            ent = self.entries.get(self._key(prompt, k * span))
            if ent is not None:
                self._tick += 1
                ent.last_used = self._tick
                self.hits += 1
                return ent
        self.misses += 1
        return None

    def reclaim(self, need_free: int) -> int:
        """Evict LRU entries until the table's free list holds at least
        ``need_free`` pages (or the cache is empty).  Returns entries
        dropped."""
        dropped = 0
        while (self.table.free_pages < need_free and self.entries):
            key = min(self.entries, key=lambda k: self.entries[k].last_used)
            self.table.free(list(self.entries.pop(key).pages))
            dropped += 1
        return dropped

    def clear(self) -> None:
        for ent in self.entries.values():
            self.table.free(list(ent.pages))
        self.entries.clear()


# --------------------------------------------------------------------------
# the unified cache object
# --------------------------------------------------------------------------

@dataclass
class AdmitPlan:
    """What admitting one request would take / reuse."""
    total_pages: int                   # logical pages the request needs
    fresh_pages: int                   # pages to pull off the free list
    shared: tuple = ()                 # prefix pages mapped COW
    covered: int = 0                   # prompt tokens already in cache


@dataclass
class KVCache:
    """One cache object, one lifecycle: ``alloc -> append/fork -> free``.

    Wraps the device storage pytree plus, in paged mode, the
    ``PageTable`` / ``PrefixCache`` and the per-slot page lists.  The
    legacy dense rowset (``paging=None``) lives behind the same object
    so the engine has a single construction path and the equivalence
    oracles keep running; its ``grow_from``/``insert_row``/``reset_row``
    methods replace the old module-level free functions.

    Build via ``runtime.serve.make_kv_cache`` (which owns the shape /
    sharding derivation) — this class never imports the runtime."""
    storage: object                    # device pytree (dense or pooled)
    layout: object                     # ServeLayout
    paging: PagedLayout | None = None
    sharding: object = None            # pytree of NamedShardings
    table: PageTable | None = None
    prefix: PrefixCache | None = None
    slot_pages: dict = field(default_factory=dict)   # slot -> [page ids]
    slot_state: dict = field(default_factory=dict)   # slot -> state row
    _state_free: list = field(default_factory=list)
    _reserved: dict = field(default_factory=dict)    # key -> (pages, srow)
    _jit_cache: dict = field(default_factory=dict)
    cow_copies: int = 0                # pages forked private on write

    def __post_init__(self):
        if self.paging is not None:
            if self.table is None:
                self.table = PageTable(self.paging.n_pages)
            self._state_free = list(range(self.paging.n_state_pages))

    # -- paged lifecycle ---------------------------------------------------
    @property
    def paged(self) -> bool:
        return self.paging is not None

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.paging.span)

    def plan(self, prompt, max_new_tokens: int,
             use_prefix: bool = True, full_row: bool = False) -> AdmitPlan:
        """Pages required to serve ``prompt`` + generation, after
        prefix reuse.  Host-side only — commits nothing.  ``full_row``
        allocates the whole logical row (paged prism: the means state
        is defined over the full prefill region, so rows are never
        partial and prefixes are never shared)."""
        total = (self.paging.pages_per_row if full_row
                 else self.pages_needed(len(prompt) + max_new_tokens))
        shared, covered = (), 0
        if use_prefix and not full_row and self.prefix is not None:
            ent = self.prefix.lookup(prompt, self.paging.span)
            if ent is not None:
                shared, covered = ent.pages, ent.tokens
        return AdmitPlan(total_pages=total,
                         fresh_pages=total - len(shared),
                         shared=shared, covered=covered)

    def can_admit(self, plan: AdmitPlan, *, reclaim: bool = True) -> bool:
        """Free-list check for one plan; optionally reclaims LRU prefix
        entries to make room.  The scheduler's page-aware admission
        gate."""
        if self.table.free_pages >= plan.fresh_pages \
                and self._state_free:
            return True
        if reclaim and self.prefix is not None:
            self.prefix.reclaim(plan.fresh_pages)
        return (self.table.free_pages >= plan.fresh_pages
                and bool(self._state_free))

    def reserve(self, key, plan: AdmitPlan) -> bool:
        """Phase one of admission: commit the plan's pages to ``key``
        (the request id) WITHOUT binding a slot yet.  All-or-nothing;
        returns False (committing nothing) when the free list or the
        state pool cannot cover it.  Reserving at the admission gate —
        before the scheduler pops the next queued request — keeps the
        free-list arithmetic honest when several requests admit in one
        engine loop."""
        assert key not in self._reserved, f"double reserve of {key!r}"
        if not self._state_free:
            return False
        fresh = self.table.alloc(plan.fresh_pages)
        if fresh is None:
            return False
        if plan.shared:
            self.table.share(plan.shared)
        self._reserved[key] = (list(plan.shared) + fresh,
                               self._state_free.pop())
        return True

    def bind(self, key, slot: int) -> None:
        """Phase two: attach a reservation to the slot the scheduler
        assigned."""
        assert slot not in self.slot_pages, f"slot {slot} already mapped"
        pages, srow = self._reserved.pop(key)
        self.slot_pages[slot] = pages
        self.slot_state[slot] = srow

    def cancel(self, key) -> None:
        """Drop an unbound reservation (requeue / shutdown)."""
        pages, srow = self._reserved.pop(key)
        self.table.free(pages)
        self._state_free.append(srow)

    def alloc(self, slot: int, plan: AdmitPlan) -> AdmitPlan:
        """Commit a plan straight to a slot (``reserve`` + ``bind``
        fused — the single-request path and the unit tests').  Raises
        if the free list cannot cover it (call ``can_admit`` first)."""
        assert slot not in self.slot_pages, f"slot {slot} already mapped"
        if not self.reserve(("__alloc__", slot), plan):
            raise RuntimeError(
                f"out of pages: need {plan.fresh_pages}, "
                f"free {self.table.free_pages}, "
                f"state rows free {len(self._state_free)}")
        self.bind(("__alloc__", slot), slot)
        return plan

    def append(self, slot: int, n_tokens: int) -> None:
        """Grow a live request's page list to cover ``n_tokens`` total
        positions (no-op when the eager allocation already covers them
        — the deadlock-free default; an offload tier would allocate
        lazily here)."""
        need = self.pages_needed(n_tokens)
        have = len(self.slot_pages[slot])
        if need > have:
            extra = self.table.alloc(need - have)
            if extra is None:
                raise RuntimeError(f"out of pages appending slot {slot}")
            self.slot_pages[slot].extend(extra)

    def fork_cow(self, src_pages, slot: int, n_fresh: int) -> list:
        """Map ``src_pages`` copy-on-write into ``slot`` and extend
        with ``n_fresh`` private pages — the raw share primitive under
        ``alloc(plan)`` (exposed for tests and future schedulers)."""
        assert slot not in self.slot_pages
        fresh = self.table.alloc(n_fresh)
        if fresh is None:
            raise RuntimeError("out of pages in fork_cow")
        self.table.share(src_pages)
        self.slot_pages[slot] = list(src_pages) + fresh
        if not self._state_free:
            raise RuntimeError("out of state pages")
        self.slot_state[slot] = self._state_free.pop()
        return self.slot_pages[slot]

    def ensure_writable(self, slot: int, first_pos: int,
                        last_pos: int) -> int:
        """Copy-on-write fork: any page in the slot's write window
        [first_pos, last_pos] that is still shared (refcount > 1) is
        copied to a fresh private page before the tick writes it.  With
        the admission-time covered < len(prompt) invariant this never
        fires — it is the safety valve that keeps future policies
        (speculative rewind past a shared boundary, offload restore)
        honest.  Returns pages forked."""
        if first_pos > last_pos:
            return 0
        pages = self.slot_pages[slot]
        j0 = first_pos // self.paging.span
        j1 = min(last_pos // self.paging.span, len(pages) - 1)
        forked = 0
        for j in range(j0, j1 + 1):
            if self.table.refs[pages[j]] > 1:
                new = self.table.alloc(1)
                if new is None:
                    raise RuntimeError("out of pages in COW fork")
                self._copy_page(pages[j], new[0])
                self.table.free([pages[j]])
                pages[j] = new[0]
                forked += 1
                self.cow_copies += 1
        return forked

    def free(self, slot: int, prompt=None) -> None:
        """Release a finished request's pages (refcount--; shared
        prefix pages survive under their cache entries).  When
        ``prompt`` is given and a prefix cache is attached, the
        prompt's full pages are registered for reuse first."""
        pages = self.slot_pages.pop(slot)
        if prompt is not None and self.prefix is not None:
            self.prefix.register(prompt, pages, self.paging.span)
        self.table.free(pages)
        self._state_free.append(self.slot_state.pop(slot))

    # -- host offload tier (spill / restore) -------------------------------
    def spill(self, key, slot: int, store, *, tokens: int = 0) -> int:
        """Spill ``slot``'s entire cache footprint to the host ``store``
        under ``key`` (the request id): ONE device→host gather of every
        page the slot holds plus — in prism mode — its kz/vz/gz/zsum
        state row, then the refcount handoff: the pages go back to the
        table (COW-shared prefix pages just decref; their content was
        gathered, so the restored copy is private and bit-identical),
        the state row returns to the pool, and the request's only live
        copy is the host entry.  ``tokens`` records the covered-token
        count ``plan_restore`` will report.  Returns pages spilled."""
        pages = self.slot_pages.pop(slot)
        srow = self.slot_state.pop(slot)
        payload = (self._extract(pages, srow)
                   if self.storage is not None else None)
        store.put(key, len(pages), payload, tokens=tokens)
        self.table.free(pages)
        self._state_free.append(srow)
        return len(pages)

    def plan_restore(self, key, store, *, retries: int = 0,
                     backoff_s: float = 0.0) -> AdmitPlan | None:
        """Admission plan for restoring a spilled request — the same
        shape ``plan`` returns, but the covered-token count comes from
        the store instead of the prefix cache and every page is fresh
        (content arrives by injection, not sharing).  ``retries``
        re-reads through transient store losses (``KVStore.get``)
        before giving up.  Returns None when the store lost the entry
        for good (host-memory pressure): the caller must fall back to a
        plain re-prefill plan."""
        ent = store.get(key, retries=retries, backoff_s=backoff_s)
        if ent is None:
            return None
        return AdmitPlan(total_pages=ent.n_pages, fresh_pages=ent.n_pages,
                         covered=ent.tokens)

    def restore(self, key, slot: int, store, *, retries: int = 0,
                backoff_s: float = 0.0) -> bool:
        """Inject the spilled content for ``key`` into the fresh pages
        just bound to ``slot`` (``plan_restore`` → ``reserve`` →
        ``bind`` must have run).  Pages are physically different from
        the ones spilled; the page/state maps make relocation invisible
        to the step programs, so decode resumes bit-equal in both
        cache modes.  ``retries`` re-reads through transient store
        losses before giving up.  Returns False when the store dropped
        the entry between planning and binding — the pages stay bound
        (the restore plan is never smaller than a re-prefill plan for
        the same request), so the caller just re-prefills into them."""
        ent = store.get(key, retries=retries, backoff_s=backoff_s,
                        consume=True)
        if ent is None:
            return False
        pages = self.slot_pages[slot]
        assert len(pages) == ent.n_pages, (len(pages), ent.n_pages)
        if self.storage is not None and ent.payload is not None:
            self._inject(pages, self.slot_state[slot], ent.payload)
        return True

    # -- device-side maps --------------------------------------------------
    def page_map(self, n_slots: int) -> np.ndarray:
        """(n_slots, pages_per_row) int32 physical-page map fed to the
        step programs each tick; unmapped logical slots are NO_PAGE."""
        m = np.full((n_slots, self.paging.pages_per_row), NO_PAGE,
                    np.int32)
        for slot, pages in self.slot_pages.items():
            m[slot, :len(pages)] = pages
        return m

    def state_map(self, n_slots: int) -> np.ndarray:
        """(n_slots,) int32 state-page row per slot (prism means pool)."""
        m = np.full((n_slots,), NO_PAGE, np.int32)
        for slot, row in self.slot_state.items():
            m[slot] = row
        return m

    # -- device ops --------------------------------------------------------
    def _jit(self, name, fn, donate=True):
        if name not in self._jit_cache:
            self._jit_cache[name] = jax.jit(
                fn, donate_argnums=(0,) if donate else (),
                out_shardings=self.sharding)
        return self._jit_cache[name]

    def _copy_page(self, src: int, dst: int) -> None:
        """Device copy of one pool page row src -> dst on every k/v
        pool leaf (scan leaves carry the page dim at axis 1, tail at
        axis 0) — the COW fork primitive."""
        import jax.numpy as jnp

        def body(storage, s, d):
            def one(tree, axis):
                out = {}
                for key, v in tree.items():
                    if key in ("k", "v"):
                        row = lax.dynamic_slice_in_dim(v, s, 1, axis=axis)
                        v = lax.dynamic_update_slice_in_dim(
                            v, row, d, axis=axis)
                    out[key] = v
                return out
            return {"scan": [one(t, 1) for t in storage["scan"]],
                    "tail": [one(t, 0) for t in storage["tail"]]}
        prog = self._jit("copy_page", body)
        self.storage = prog(self.storage, jnp.asarray(src, jnp.int32),
                            jnp.asarray(dst, jnp.int32))

    def copy_state(self, src_row: int, dst_row: int) -> None:
        """Device copy of one means-state pool row (kz/vz/gz/zsum) —
        the snapshot/restore primitive a prism offload tier needs."""
        import jax.numpy as jnp

        def body(storage, s, d):
            def one(tree, axis):
                out = {}
                for key, v in tree.items():
                    if key in ("kz", "vz", "gz", "zsum"):
                        row = lax.dynamic_slice_in_dim(v, s, 1, axis=axis)
                        v = lax.dynamic_update_slice_in_dim(
                            v, row, d, axis=axis)
                    out[key] = v
                return out
            return {"scan": [one(t, 1) for t in storage["scan"]],
                    "tail": [one(t, 0) for t in storage["tail"]]}
        prog = self._jit("copy_state", body)
        self.storage = prog(self.storage, jnp.asarray(src_row, jnp.int32),
                            jnp.asarray(dst_row, jnp.int32))

    def _extract(self, pages, srow: int):
        """Gather one request's pages (+ state row) off the device in a
        single jitted gather + ONE ``device_get`` — the spill path.
        The result is a host pytree mirroring the storage structure but
        holding only this request's slice."""
        import jax.numpy as jnp

        key = ("extract", len(pages))
        if key not in self._jit_cache:
            def body(storage, idx, sr):
                def one(tree, axis):
                    out = {}
                    for k, v in tree.items():
                        if k in ("k", "v"):
                            out[k] = jnp.take(v, idx, axis=axis)
                        elif k in ("kz", "vz", "gz", "zsum"):
                            out[k] = lax.dynamic_slice_in_dim(
                                v, sr, 1, axis=axis)
                    return out
                return {"scan": [one(t, 1) for t in storage["scan"]],
                        "tail": [one(t, 0) for t in storage["tail"]]}
            self._jit_cache[key] = jax.jit(body)
        out = self._jit_cache[key](self.storage,
                                   jnp.asarray(pages, jnp.int32),
                                   jnp.asarray(srow, jnp.int32))
        return jax.device_get(out)

    def _inject(self, pages, srow: int, payload) -> None:
        """Scatter a spilled payload back into (new) physical pages and
        a (new) state row — the restore path, exact inverse of
        ``_extract`` up to page relocation."""
        import jax.numpy as jnp

        key = ("inject", len(pages))
        if key not in self._jit_cache:
            def body(storage, pl, idx, sr):
                def one(tree, p, axis):
                    out = {}
                    for k, v in tree.items():
                        if k in ("k", "v"):
                            data = p[k].astype(v.dtype)
                            v = (v.at[idx].set(data) if axis == 0
                                 else v.at[:, idx].set(data))
                        elif k in ("kz", "vz", "gz", "zsum"):
                            v = lax.dynamic_update_slice_in_dim(
                                v, p[k].astype(v.dtype), sr, axis=axis)
                        out[k] = v
                    return out
                return {"scan": [one(t, p, 1) for t, p in
                                 zip(storage["scan"], pl["scan"])],
                        "tail": [one(t, p, 0) for t, p in
                                 zip(storage["tail"], pl["tail"])]}
            self._jit_cache[key] = jax.jit(
                body, donate_argnums=(0,), out_shardings=self.sharding)
        self.storage = self._jit_cache[key](self.storage, payload,
                                            jnp.asarray(pages, jnp.int32),
                                            jnp.asarray(srow, jnp.int32))

    # -- fault injection + quarantine (runtime/faults.py) ------------------
    def poison_page(self, page: int) -> None:
        """NaN-fill one pool page's k/v content — the ``page_poison``
        chaos fault (a simulated device-memory corruption).  Pure
        content damage: the page table, maps, and refcounts are
        untouched, so ONLY reads through this physical page see the
        poison — which is exactly what the isfinite quarantine test
        needs to prove neighbour isolation."""
        import jax.numpy as jnp

        def body(storage, p):
            def one(tree, axis):
                out = {}
                for key, v in tree.items():
                    if key in ("k", "v"):
                        row = lax.dynamic_slice_in_dim(v, p, 1, axis=axis)
                        v = lax.dynamic_update_slice_in_dim(
                            v, jnp.full_like(row, jnp.nan), p, axis=axis)
                    out[key] = v
                return out
            return {"scan": [one(t, 1) for t in storage["scan"]],
                    "tail": [one(t, 0) for t in storage["tail"]]}
        prog = self._jit("poison_page", body)
        self.storage = prog(self.storage, jnp.asarray(page, jnp.int32))

    def scrub_slot(self, slot: int) -> int:
        """Zero the slot's PRIVATE (refcount == 1) pages plus its
        means-state row — the quarantine step before an in-place
        re-prefill, and the decontamination step before failed pages
        rejoin the free list.  Zeroing (not just overwriting) matters:
        masked attention still computes ``0 * NaN = NaN`` over dead
        columns, so poisoned content must be physically cleared before
        any slot reads through these pages again.  COW-shared pages are
        skipped — other holders read them, and the poison fault never
        targets a shared page.  Returns pages scrubbed."""
        import jax.numpy as jnp

        pages = [p for p in self.slot_pages[slot]
                 if self.table.refs[p] == 1]
        srow = self.slot_state[slot]
        if not pages:
            return 0
        key = ("scrub", len(pages))
        if key not in self._jit_cache:
            def body(storage, idx, sr):
                def one(tree, axis):
                    out = {}
                    for k, v in tree.items():
                        if k in ("k", "v"):
                            zeros_sh = ((len(pages),) + v.shape[1:]
                                        if axis == 0 else
                                        v.shape[:1] + (len(pages),)
                                        + v.shape[2:])
                            z = jnp.zeros(zeros_sh, v.dtype)
                            v = (v.at[idx].set(z) if axis == 0
                                 else v.at[:, idx].set(z))
                        elif k in ("kz", "vz", "gz", "zsum"):
                            row = lax.dynamic_slice_in_dim(
                                v, sr, 1, axis=axis)
                            v = lax.dynamic_update_slice_in_dim(
                                v, jnp.zeros_like(row), sr, axis=axis)
                        out[k] = v
                    return out
                return {"scan": [one(t, 1) for t in storage["scan"]],
                        "tail": [one(t, 0) for t in storage["tail"]]}
            self._jit_cache[key] = jax.jit(
                body, donate_argnums=(0,), out_shardings=self.sharding)
        self.storage = self._jit_cache[key](self.storage,
                                            jnp.asarray(pages, jnp.int32),
                                            jnp.asarray(srow, jnp.int32))
        return len(pages)

    # -- snapshot / restore (engine journal) -------------------------------
    def extract_slot(self, slot: int):
        """One live slot's full cache footprint as a host pytree — the
        engine-snapshot path, same bit-exact gather as ``spill`` but
        non-destructive (the slot keeps its pages).  None in host-only
        bookkeeping mode."""
        if self.storage is None:
            return None
        return self._extract(self.slot_pages[slot], self.slot_state[slot])

    def inject_slot(self, slot: int, payload) -> None:
        """Scatter a journalled footprint into the (fresh) pages bound
        to ``slot`` — the engine-restore path."""
        if self.storage is None or payload is None:
            return
        self._inject(self.slot_pages[slot], self.slot_state[slot], payload)

    # -- dense-rowset lifecycle (legacy oracle path) -----------------------
    def grow_from(self, prefill_cache, lay_from):
        """Dense mode: pad a prefill-sized cache to this cache's decode
        capacity (replaces the free ``grow_cache``)."""
        prog = self._jit(
            ("grow", id(lay_from)),
            functools.partial(grow_rows, lay_from=lay_from,
                              lay_to=self.layout), donate=False)
        return prog(prefill_cache)

    def insert_row(self, src, src_row: int, dst_row: int) -> None:
        """Dense mode: splice row ``src_row`` of ``src`` into this
        cache (replaces the free ``insert_cache_row``)."""
        import jax.numpy as jnp
        prog = self._jit("insert", splice_row)
        self.storage = prog(self.storage, src,
                            jnp.asarray(src_row, jnp.int32),
                            jnp.asarray(dst_row, jnp.int32))

    def reset_row(self, row: int) -> None:
        """Dense mode: zero one slot row (replaces ``reset_cache_row``)."""
        import jax.numpy as jnp
        prog = self._jit("reset", zero_row)
        self.storage = prog(self.storage, jnp.asarray(row, jnp.int32))

    # -- invariants / stats ------------------------------------------------
    def check(self) -> None:
        """Full page-accounting invariant: table consistency plus
        every page's refcount equals the number of holders (slot page
        lists + prefix entries) that name it."""
        self.table.check()
        held = np.zeros(self.paging.n_pages, np.int64)
        for pages in self.slot_pages.values():
            for p in pages:
                held[p] += 1
        for pages, _ in self._reserved.values():
            for p in pages:
                held[p] += 1
        if self.prefix is not None:
            for ent in self.prefix.entries.values():
                for p in ent.pages:
                    held[p] += 1
        assert np.array_equal(held, self.table.refs.astype(np.int64)), \
            (held.tolist(), self.table.refs.tolist())
        srows = (sorted(self.slot_state.values())
                 + [s for _, s in self._reserved.values()]
                 + sorted(self._state_free))
        assert sorted(srows) == list(range(self.paging.n_state_pages))

    def stats(self) -> dict:
        if not self.paged:
            return {}
        return {"pages_total": self.paging.n_pages,
                "pages_free": self.table.free_pages,
                "pages_used": self.table.used_pages,
                "prefix_entries": (len(self.prefix.entries)
                                   if self.prefix else 0),
                "prefix_hits": self.prefix.hits if self.prefix else 0,
                "cow_copies": self.cow_copies}

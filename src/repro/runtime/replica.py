"""Segment-Means standby replicas — the redundancy layer behind
degraded-mesh serving (``shard_loss`` in ``runtime/faults.py``).

PRISM's own compression is the natural replication mechanism: each
sequence shard's Segment-Means summary (kz/vz + repeat counts gz) is
CR× smaller than its raw KV, so keeping a standby copy of EVERY live
request's per-shard means is nearly free.  When a shard drops out of
the mesh mid-decode, the degraded step program
(``make_serve_step(degraded=True)``) masks the lost shard's exact
columns out of the flash-decode stat combine and substitutes its
replicated means columns through the existing ``+log g`` bias path —
in-flight requests keep emitting finite tokens with PRISM-bounded
quality loss instead of failing outright.

One :class:`MeansReplica` per engine, armed only when a ``shard_loss``
fault is schedulable (paged cache required — captures ride the
``KVCache.extract_slot`` gather).  It piggybacks the engine tick:

  * **capture** — on a slot's first decode tick (and on a bounded
    staleness-driven refresh schedule) the slot's cache footprint is
    gathered host-side once.  In ``prism`` decode mode the means state
    row (kz/vz/gz/zsum) is copied verbatim — though the paged prism
    state pool is already replicated across the sequence shards, so
    the cache itself survives a shard loss and this host copy is the
    belt-and-braces standby.  In ``exact`` decode mode no means exist
    yet, so the replica CUTS them: per lost-able shard, the captured
    roped K / V rows split into ``L`` contiguous segments and their
    column means become the standby kz/vz with gz = real-token counts
    (the same shard-major ``n_seq·L`` column grid the prism cache
    uses).
  * **staleness** — each capture records the covered position count;
    positions decoded after the capture are NOT in any replica column
    and are simply lost with the shard (``staleness(slot) = written -
    covered``).  The engine bounds this with its refresh schedule.
  * **assemble** — one device tree per degraded tick: the per-layer
    (B, n_seq·L) means batch the degraded exact program consumes,
    zero-rowed (gz = 0 → dead columns) for slots with no capture.

Replicas never capture DURING a degraded window — the lost shard's
device memory is exactly what the fault declared unreadable, and a
gather would read through it.  Recovery (engine-orchestrated
``reset_for_refill`` re-prefill) drops every replica; captures resume
with the rebuilt exact KV.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.segment_means import segment_sizes


def _seg_counts(k: int, L: int) -> np.ndarray:
    """Per-segment real-token counts for ``k`` filled columns over an
    ``L``-segment grid: the paper's split when ``k >= L``; one token
    per leading segment (trailing segments dead, gz = 0) when the slot
    holds fewer columns than segments."""
    if k <= 0:
        return np.zeros(L, np.int64)
    if k < L:
        sizes = np.zeros(L, np.int64)
        sizes[:k] = 1
        return sizes
    return segment_sizes(k, L)


def _filled_local_cols(lay, shard: int, covered: int) -> int:
    """How many LOCAL cache columns of ``shard`` hold real positions
    once [0, covered) are written, under the layout's placement."""
    if covered <= 0:
        return 0
    if lay.placement == "rr":
        # position p -> shard p % n_seq, local col p // n_seq
        return (covered - shard + lay.n_seq - 1) // lay.n_seq \
            if covered > shard else 0
    # aligned: prefill block [s·n_loc0, (s+1)·n_loc0) then round-robin
    n0, n_loc0 = lay.prefill_len, lay.n_loc0
    pre = int(np.clip(covered - shard * n_loc0, 0, n_loc0))
    extra = covered - n0
    dec = (extra - shard + lay.n_seq - 1) // lay.n_seq \
        if extra > shard else 0
    return pre + max(0, dec)


def _local_positions(lay, shard: int, n_cols: int) -> np.ndarray:
    """Global position of each local column [0, n_cols) on ``shard``
    (the inverse of ``runtime.serve._decode_cols``'s col_pos map)."""
    j = np.arange(n_cols)
    if lay.placement == "rr":
        return j * lay.n_seq + shard
    n0, n_loc0 = lay.prefill_len, lay.n_loc0
    return np.where(j < n_loc0, shard * n_loc0 + j,
                    n0 + (j - n_loc0) * lay.n_seq + shard)


@dataclass
class _SlotReplica:
    """One slot's standby state: per-layer means trees + freshness."""
    rid: int
    epoch: int
    covered: int                       # positions the capture covers
    tick: int                          # engine tick of the capture
    pages: tuple                       # page-table metadata at capture
    state_row: int | None              # prism state row at capture
    layers: dict = field(default_factory=dict)   # {"scan": [...], ...}
    nbytes: int = 0


class MeansReplica:
    """Host-side standby replica of every live slot's per-shard
    Segment-Means state (see module docstring).  Pure numpy + one
    ``extract_slot`` gather per capture; ``assemble()`` is the only
    device transfer and is cached until the replica set changes."""

    def __init__(self, cfg, lay, hp, paging, n_slots: int,
                 refresh_every: int = 16):
        self.cfg, self.lay, self.hp = cfg, lay, hp
        self.paging = paging
        self.n_slots = int(n_slots)
        self.refresh_every = max(1, int(refresh_every))
        self.m = lay.n_seq * lay.L
        #: shard each replica column belongs to (shard-major, the same
        #: grid ``runtime.serve._means_meta`` uses)
        self.shard_of = np.repeat(np.arange(lay.n_seq), lay.L)
        self._slots: dict[int, _SlotReplica] = {}
        self._assembled = None         # device-tree cache
        self.captures = 0
        self.refreshes = 0

    # -- capture --------------------------------------------------------
    def has(self, slot: int, st) -> bool:
        rep = self._slots.get(slot)
        return (rep is not None and rep.rid == st.req.rid
                and rep.epoch == st.epoch)

    def staleness(self, slot: int, st) -> int:
        """Positions written since the capture (lost with the shard)."""
        rep = self._slots.get(slot)
        if rep is None or rep.rid != st.req.rid or rep.epoch != st.epoch:
            return 1 << 30
        return max(0, (st.pos + 1) - rep.covered)

    def tick(self, kv, states, tick_no: int) -> int:
        """The per-tick piggyback: capture every decoding slot that has
        no current replica, plus AT MOST ONE staleness refresh (the
        stalest slot past ``refresh_every``) so the host gather cost
        stays O(1) per tick at steady state.  Returns captures made."""
        made = 0
        stalest, worst = None, 0
        for st in states:
            if not self.has(st.slot, st):
                self.capture(kv, st, tick_no)
                made += 1
            else:
                s = self.staleness(st.slot, st)
                if s >= self.refresh_every and s > worst:
                    stalest, worst = st, s
        if stalest is not None:
            self.capture(kv, stalest, tick_no)
            self.refreshes += 1
            made += 1
        return made

    def capture(self, kv, st, tick_no: int = 0) -> None:
        """Gather ``st``'s cache footprint and cut/copy its standby
        means.  ``covered = st.pos + 1`` — every position a decoding
        slot has fed is written (the rewind rewrite included)."""
        slot = st.slot
        covered = int(st.pos) + 1
        payload = kv.extract_slot(slot)
        if payload is None:            # host-only bookkeeping mode
            return
        if self.hp.decode_mode == "prism":
            layers = self._copy_state(payload)
        else:
            layers = self._cut_means(payload, covered)
        nbytes = int(sum(a.nbytes for t in layers["scan"] + layers["tail"]
                         for a in t.values()))
        self._slots[slot] = _SlotReplica(
            rid=st.req.rid, epoch=st.epoch, covered=covered,
            tick=int(tick_no),
            pages=tuple(kv.slot_pages.get(slot, ())),
            state_row=kv.slot_state.get(slot),
            layers=layers, nbytes=nbytes)
        self.captures += 1
        self._assembled = None

    def _copy_state(self, payload) -> dict:
        """Prism: the cache already carries the means — copy the state
        row (squeezing the width-1 row axis the extract keeps)."""
        def one(tree, axis):
            out = {}
            for k in ("kz", "vz", "gz"):
                if k in tree:
                    # scan: (n_units, 1, m, ...) -> (n_units, m, ...);
                    # tail: (1, m, ...) -> (m, ...)
                    out[k] = np.asarray(tree[k]).squeeze(axis)
            return out
        return {"scan": [one(t, 1) for t in payload["scan"]],
                "tail": [one(t, 0) for t in payload["tail"]]}

    def _cut_means(self, payload, covered: int) -> dict:
        """Exact mode: cut shard-major Segment-Means from the captured
        roped K / V pages.  Payload k/v leaves are the slot's pages
        gathered over the GLOBAL pool column dim — scan
        (n_units, P, pool_cap, Hkv, hd), tail (P, pool_cap, Hkv, hd) —
        where pool column ``s·pc + t`` of page ``q`` is shard ``s``'s
        local column ``q·pc + t``."""
        lay, L = self.lay, self.lay.L
        pc = self.paging.page_cols

        def one(tree, page_axis):
            if "k" not in tree:
                return {}
            k = np.asarray(tree["k"])
            v = np.asarray(tree["v"])
            n_pages = k.shape[page_axis]
            lead = k.shape[:page_axis]            # () or (n_units,)
            hkv, hd = k.shape[-2], k.shape[-1]
            kz = np.zeros(lead + (self.m, hkv, hd), k.dtype)
            vz = np.zeros(lead + (self.m, hkv, hd), v.dtype)
            gz = np.zeros(lead + (self.m,), np.float32)
            for s in range(lay.n_seq):
                filled = _filled_local_cols(lay, s, covered)
                filled = min(filled, n_pages * pc)
                if filled <= 0:
                    continue
                # local cols [0, filled) of shard s, page-major order
                j = np.arange(filled)
                sel = (j // pc, s * pc + j % pc)  # (page, pool col)
                if page_axis == 0:
                    ks = k[sel[0], sel[1]]        # (filled, Hkv, hd)
                    vs = v[sel[0], sel[1]]
                else:
                    ks = k[:, sel[0], sel[1]]     # (n_units, filled, ..)
                    vs = v[:, sel[0], sel[1]]
                sizes = _seg_counts(filled, L)
                start = 0
                for c, n in enumerate(sizes):
                    if n <= 0:
                        continue
                    col = s * L + c
                    sl = slice(start, start + int(n))
                    kz[..., col, :, :] = ks[..., sl, :, :].mean(axis=-3)
                    vz[..., col, :, :] = vs[..., sl, :, :].mean(axis=-3)
                    gz[..., col] = float(n)
                    start += int(n)
            return {"kz": kz, "vz": vz, "gz": gz}
        return {"scan": [one(t, 1) for t in payload["scan"]],
                "tail": [one(t, 0) for t in payload["tail"]]}

    # -- drop -----------------------------------------------------------
    def drop(self, slot: int) -> None:
        if self._slots.pop(slot, None) is not None:
            self._assembled = None

    def drop_all(self) -> None:
        if self._slots:
            self._assembled = None
        self._slots.clear()

    # -- assemble (degraded exact program input) -------------------------
    def lost_mask(self, lost) -> np.ndarray:
        """(n_seq,) float32 mask the degraded program takes: 1.0 marks
        an unreadable shard."""
        m = np.zeros(self.lay.n_seq, np.float32)
        for s in lost:
            m[int(s) % self.lay.n_seq] = 1.0
        return m

    def assemble(self):
        """The degraded EXACT program's replica input: per layer a
        batched {"kz" (B, m, Hkv, hd), "vz", "gz" (B, m)} tree (scan
        units stacked with leading n_units), zero rows — gz = 0, dead
        columns — for slots with no standby.  Built once per replica-set
        change, then served from the device cache."""
        if self._assembled is not None:
            return self._assembled
        import jax.numpy as jnp

        cfg, B, m = self.cfg, self.n_slots, self.m
        hkv, hd = cfg.n_kv_heads, cfg.hd
        u, n_units, _ = cfg.scan_split
        kinds = cfg.block_kinds

        def zeros(kind, lead):
            if kind not in ("attn", "moe", "shared_attn"):
                return {}
            sh = (lead + (B,) if lead else (B,))
            return {"kz": np.zeros(sh + (m, hkv, hd), np.float32),
                    "vz": np.zeros(sh + (m, hkv, hd), np.float32),
                    "gz": np.zeros(sh + (m,), np.float32)}
        host = {"scan": [zeros(kinds[j], (n_units,)) for j in range(u)],
                "tail": [zeros(kinds[n_units * u + t], ())
                         for t in range(len(kinds) - n_units * u)]}
        for slot, rep in self._slots.items():
            for dst, src in zip(host["scan"], rep.layers["scan"]):
                for key in dst:
                    dst[key][:, slot] = src[key]
            for dst, src in zip(host["tail"], rep.layers["tail"]):
                for key in dst:
                    dst[key][slot] = src[key]
        self._assembled = {
            "scan": [{k: jnp.asarray(v) for k, v in t.items()}
                     for t in host["scan"]],
            "tail": [{k: jnp.asarray(v) for k, v in t.items()}
                     for t in host["tail"]]}
        return self._assembled

    # -- introspection ---------------------------------------------------
    def stats(self) -> dict:
        return {"slots": len(self._slots),
                "captures": self.captures,
                "refreshes": self.refreshes,
                "bytes": int(sum(r.nbytes for r in self._slots.values())),
                "covered": {s: r.covered
                            for s, r in sorted(self._slots.items())}}

"""Deterministic fault injection for the serving stack.

PRISM targets edge deployments where hosts lose memory, links stall,
and accelerator state silently corrupts.  This module is the ONE
mechanism the engine uses to rehearse those failures on purpose: a
seeded :class:`FaultInjector` driven by a declarative
:class:`FaultPlan`, wired into the existing seams —

  ===================  ==================================================
  fault kind           seam (where the engine consults the injector)
  ===================  ==================================================
  ``store_put_loss``   ``KVStore.put`` — the spilled entry vanishes
                       (host-memory pressure); the request later takes
                       the restore-miss → ``reset_for_refill`` path.
  ``store_get_loss``   ``KVStore.peek``/``pop`` — the entry existed but
                       is lost at read time (torn host state).
  ``page_poison``      ``ServingEngine`` pre-tick — NaN-fill one live,
                       *private* (refcount == 1) cache page of a
                       decoding slot; the isfinite guard must quarantine
                       exactly that slot.
  ``admission_stall``  ``ServingEngine`` admission — skip this tick's
                       admissions (a stuck control plane).
  ``tick_delay``       ``ServingEngine.step`` — the whole tick does
                       nothing (a stalled device / dropped heartbeat).
  ``shard_loss``       ``ServingEngine`` pre-tick — one sequence shard's
                       KV becomes unreadable (a device dropping out of
                       the mesh).  The engine enters DEGRADED mode: the
                       lost shard's exact columns are masked out of the
                       decode combine and substituted by its replicated
                       Segment-Means columns (``runtime/replica.py``),
                       then every affected request recovers via the
                       deterministic re-prefill path.  ``FaultSpec.shard``
                       pins the victim shard index; ``None`` draws it
                       from the kind's seeded stream.
  ===================  ==================================================

Every decision is a pure function of ``(seed, kind, op index)``: the
same plan over the same request trace injects the same faults, so chaos
runs are replayable and the CI soak can assert token-identical recovery
against a clean run (per-request seeded sampling makes tokens
independent of timing, slots, and restarts).

This replaces PR 7's ad-hoc ``KVStore(capacity_bytes=0)`` "flaky
store" configuration as the way to rehearse lost entries (the zero-
capacity store still works — it is just a capacity policy now, not the
fault-injection story).
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields

import numpy as np


#: the closed set of injectable fault kinds (taxonomy in docs/serving.md)
KINDS = ("store_put_loss", "store_get_loss", "page_poison",
         "admission_stall", "tick_delay", "shard_loss")


@dataclass(frozen=True)
class FaultSpec:
    """Injection schedule for ONE fault kind.

    ``p`` fires Bernoulli(p) per opportunity from the injector's seeded
    stream; ``at`` fires at exactly those 0-based opportunity indices
    (both may be active — a fault fires if either says so).  The
    default ``FaultSpec()`` never fires.

    ``shard`` is meaningful for ``shard_loss`` only: it pins which
    sequence shard dies when the fault fires (schedulable per shard
    index — the CI soak kills each shard in turn).  ``None`` leaves the
    victim to the injector's seeded ``pick``."""
    p: float = 0.0
    at: tuple = ()
    shard: int | None = None

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault probability {self.p} not in [0, 1]")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))
        if self.shard is not None:
            if int(self.shard) < 0:
                raise ValueError(f"shard index {self.shard} < 0")
            object.__setattr__(self, "shard", int(self.shard))

    @property
    def enabled(self) -> bool:
        return self.p > 0.0 or bool(self.at)


@dataclass(frozen=True)
class FaultPlan:
    """Declarative chaos plan: one :class:`FaultSpec` per fault kind
    plus the seed that makes the whole run replayable."""
    seed: int = 0
    store_put_loss: FaultSpec = field(default_factory=FaultSpec)
    store_get_loss: FaultSpec = field(default_factory=FaultSpec)
    page_poison: FaultSpec = field(default_factory=FaultSpec)
    admission_stall: FaultSpec = field(default_factory=FaultSpec)
    tick_delay: FaultSpec = field(default_factory=FaultSpec)
    shard_loss: FaultSpec = field(default_factory=FaultSpec)

    def spec(self, kind: str) -> FaultSpec:
        if kind not in KINDS:
            raise KeyError(f"unknown fault kind {kind!r}; "
                           f"known: {KINDS}")
        return getattr(self, kind)

    @property
    def any_enabled(self) -> bool:
        return any(self.spec(k).enabled for k in KINDS)

    @classmethod
    def chaos(cls, seed: int, **overrides) -> "FaultPlan":
        """The all-kinds soak plan the CI chaos step and ``--chaos
        SEED`` use: every fault kind enabled at rates aggressive enough
        to fire many times over a short trace while leaving the engine
        able to finish it."""
        base = dict(
            store_put_loss=FaultSpec(p=0.30),
            store_get_loss=FaultSpec(p=0.20),
            page_poison=FaultSpec(p=0.02),
            admission_stall=FaultSpec(p=0.10),
            tick_delay=FaultSpec(p=0.05),
            # rare but catastrophic: each hit costs a degraded-serving
            # window plus a re-prefill of every active request, so the
            # soak keeps it an order of magnitude below the others
            shard_loss=FaultSpec(p=0.02),
        )
        base.update(overrides)
        return cls(seed=seed, **base)


class FaultInjector:
    """Seeded runtime half of the fault plan.

    One injector per engine.  Each seam calls ``fire(kind)`` once per
    opportunity; the injector counts opportunities per kind and decides
    deterministically from its own ``(seed, kind)``-keyed RNG stream —
    per-kind streams, so enabling one fault kind never perturbs the
    schedule of another."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rngs = {k: np.random.default_rng(
            np.random.SeedSequence(entropy=plan.seed,
                                   spawn_key=(i,)))
            for i, k in enumerate(KINDS)}
        self.ops = {k: 0 for k in KINDS}        # opportunities seen
        self.injected = {k: 0 for k in KINDS}   # faults actually fired

    def fire(self, kind: str) -> bool:
        """One injection opportunity for ``kind``; True = inject."""
        spec = self.plan.spec(kind)
        i = self.ops[kind]
        self.ops[kind] += 1
        # always draw when p > 0 so the stream position tracks the op
        # index — scheduled ``at`` hits never shift later Bernoulli
        # decisions
        hit = bool(self._rngs[kind].random() < spec.p) if spec.p > 0.0 \
            else False
        if i in spec.at:
            hit = True
        if hit:
            self.injected[kind] += 1
        return hit

    def pick(self, kind: str, n: int) -> int:
        """Deterministic victim index in [0, n) for a fired fault
        (e.g. which decoding slot's page to poison)."""
        assert n >= 1
        return int(self._rngs[kind].integers(n))

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def stats(self) -> dict:
        return {"seed": self.plan.seed,
                "ops": dict(self.ops),
                "injected": dict(self.injected),
                "total_injected": self.total_injected}


def _spec_fields():
    return tuple(f.name for f in fields(FaultPlan)
                 if f.name != "seed")


assert _spec_fields() == KINDS, (_spec_fields(), KINDS)

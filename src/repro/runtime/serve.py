"""Sharded serving runtime: prefill + single-token decode with a
sequence-sharded KV cache.

The paper evaluates PRISM teacher-forced (full sequences — our *prefill*
path, where Segment-Means exchange replaces the Voltage all-gather).  For
incremental decode we add the two modes a TPU deployment needs:

  * ``exact``  — distributed flash-decoding: the cache is sharded over the
    sequence axes; each shard attends to its local cache shard and the
    partial softmax statistics (m, l, acc — O(B·H·hd), independent of N)
    are combined with pmax/psum.  This is the hardware adaptation of the
    paper's goal (never all-gather activations; per-device attention work
    is N/P, not N) and is *exact*.
  * ``prism``  — paper-faithful: each shard attends to its exact local
    cache plus the cached Segment-Means K/V of all remote shards
    (scaling-aware softmax); the output is the view of the shard that owns
    the newest position (the paper's device-owns-its-partition rule).
    On edge hardware this avoids any per-token collective; on TPU the
    owner-select psum costs the same as the exact combine, so ``exact``
    dominates for decode — recorded as a finding in EXPERIMENTS.md §Perf.

Cache layout (per layer, by block kind):
  attn/moe/shared_attn  {"k","v": (B, cap_l, Hkv, hd)} sharded over the
                        sequence axes on dim 1; prism mode adds
                        {"kz","vz": (B, P·L, Hkv, hd)} replicated means-KV.
  attn_local            {"k","v": (B, W, Hkv, hd)} ring buffer over the
                        window, replicated over ``model`` (W ≪ N/P).
  mlstm                 {"s": (B, H, dk, dv+1) f32} constant-size state.
  slstm                 {"s": (B, 3, H, hd) f32}.
  mamba                 {"s": (B, H, d_state, hd) f32,
                         "tail": (B, conv-1, d_in)} conv halo.

SSM/hybrid decode is attention-free: O(1) state per token — the reason
long_500k runs natively for xlstm/zamba2; dense archs earn it through the
PRISM-compressed (or sliding-window) cache.

Continuous batching: ``pos`` is a (B,) vector — each batch row (decode
*slot*) carries its own position, idle slots pass -1, and all cache
writes are owner-masked per row.  ``insert_cache_row`` splices a newly
prefilled request into a free slot mid-flight; ``repro.serving`` builds
the request-level engine on top of these primitives.

Chunked prefill: ``make_chunk_prefill_step`` compiles ONE program that
advances any subset of rows by up to ``chunk_len`` prompt tokens at
per-row runtime offsets, writing K/V (and, in prism mode, the
Segment-Means running state kz/vz/gz/zsum — over REAL columns only)
straight into the decode-layout cache.  The engine interleaves these
chunk calls with decode steps so long prompts no longer stall
in-flight decodes, and short prompts stop paying a full ``prefill_len``
pad-to-length forward.  Chunk attention is exact (flash-decode stats
over the already-written prefix + a per-query intra-chunk pass, merged
and psum-combined across shards), so engine output is token-identical
to the monolithic prefill path.

Token-packed serving: ``make_packed_step`` compiles ONE program per
engine tick that consumes a flat ragged batch of ``token_budget``
mixed tokens — every live decode token plus prompt-chunk tokens from
every mid-prefill request, each carrying its own ``(slot, pos, off,
is_prefill)`` metadata — and runs embed→blocks→logits once over the
real tokens (dead entries pass slot = -1).  Per-tick cost scales with
the number of REAL tokens instead of ``n_slots × chunk_len``, which is
what closes the saturation gap the chunked engine's FLOP clock
recorded against gang flushes.  Attention generalizes the chunk path's
two-pass stats trick to ragged multi-request packing: prior cache
columns go through the flash-decode stats path with per-token ``pos``,
intra-tick self columns through a segment-id-masked causal pass
(tokens of different requests never attend to each other), merged with
``merge_stats`` and psum-combined — packed ≡ chunked ≡ sequential
token-for-token in both decode modes.

Kernel routing: every decode path funnels through ``decode_attention``
below, which computes the per-shard partial softmax stats with the
fused Pallas flash-decode kernel (``kernels/decode_attention.py``) or
its two-pass jnp twin, per ``ServeHParams.backend``
(``kernels/dispatch.py``; 'auto' = compiled Pallas on TPU, jnp
elsewhere).  Prefill attention and the voltage means capture route
through ``prism_attention_op`` / ``segment_means_op`` behind the same
switch.  The dense jnp forms stay below as the test oracles.
"""
from __future__ import annotations

import collections
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from . import paging as _paging
from .paging import KVCache, PagedLayout, PrefixCache, make_paged_layout
from ..compat import axis_size, shard_map
from ..core.attention import (_gqa_logits, _gqa_output, log_repeats,
                              prism_attention)
from ..core.masks import NEG_INF
from ..core.protocol import PrismConfig
from ..core.segment_means import (segment_fill_counts, segment_means,
                                  segment_sizes, segment_bounds)
from ..kernels.decode_attention import (chunk_softmax_stats,
                                        decode_stats_reference,
                                        flash_decode_stats, merge_stats)
from ..kernels.dispatch import pallas_interpret, use_pallas
from ..kernels.ops import prism_attention_op
from ..kernels.segment_means import segment_means_op
from ..models import transformer as T
from ..models.config import ModelConfig
from ..models.layers import (AttnSpec, attn_project_q, attn_project_kv,
                             attn_output, dense, norm, mlp)
from ..models.moe import moe_apply
from ..models.ssm import (mlstm_decode, slstm_decode, mamba2_decode,
                          mlstm_apply, slstm_apply, mamba2_apply)
from ..sharding.context import ShardedPrismContext
from ..sharding.rules import gather_tree, param_specs, spec_tree
from ..launch.mesh import batch_axes, mesh_axes
from .train import embed_vp, output_table


#: Trace-time counters, bumped once per (re)trace of each step-factory
#: body.  A serving engine that caches its compiled programs correctly
#: keeps every count bounded no matter how its ticks alternate between
#: packed / decode / chunk programs — the regression test in
#: ``tests/test_packed_step.py`` asserts exactly that.
trace_counts: collections.Counter = collections.Counter()


@dataclass(frozen=True)
class ServeHParams:
    decode_mode: str = "exact"       # 'exact' | 'prism'
    decode_tp: bool = False          # Megatron-TP position-wise ops (§Perf)
    ssm_chunk: int = 128
    means_cr: float = 16.0           # CR for the prism decode means cache
    backend: str = "auto"            # kernel dispatch: 'auto'|'pallas'|'jnp'
                                     # (see repro.kernels.dispatch)


# --------------------------------------------------------------------------
# layout
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeLayout:
    """Cache placement.  The default ``'aligned'`` placement: positions
    [0, prefill_len) are *prefill-aligned* — shard ``s`` holds positions
    ``[s·n_loc0, (s+1)·n_loc0)`` in its slots ``[0, n_loc0)``.  Decoded
    positions ``p >= prefill_len`` go round-robin: shard
    ``(p - n0) % n_seq``, slot ``n_loc0 + (p - n0) // n_seq`` —
    balanced writes, static shapes, and ``p = n0 - 1`` degrades exactly to
    rewriting the final prefill slot (the dry-run's one-step case).

    The ``'rr'`` placement (paged exact mode) round-robins EVERY
    position: shard ``p % n_seq``, slot ``p // n_seq``.  A gang page of
    consecutive per-shard columns then covers a CONTIGUOUS block of
    token positions across all shards — the property prefix caching
    needs for a shared page set to equal a position prefix.  Prism mode
    keeps 'aligned' (Segment-Means shard ownership requires contiguous
    per-shard position blocks), so paged prism shares no prefixes."""
    ba: tuple                        # batch mesh axes (may be empty)
    seq_axes: tuple                  # mesh axes sharding the cache sequence
    n_seq: int                       # total sequence shards (PRISM's P)
    cap: int                         # global cache capacity (tokens)
    cap_l: int                       # per-shard capacity
    prefill_len: int                 # tokens laid down by prefill (n0)
    L: int                           # segment means per shard (prism cache)
    placement: str = "aligned"       # 'aligned' | 'rr' (paged exact)

    @property
    def bspec(self):
        return self.ba if self.ba else None

    @property
    def n_loc0(self) -> int:
        return self.prefill_len // self.n_seq


def _layout_axes(mesh, batch: int) -> tuple:
    """(batch axes, sequence axes) the layout will use: 'model' shards
    the sequence when the batch divides over the batch axes; otherwise
    (long_500k: B=1) batch is replicated and the sequence shards over
    every axis.  The single source of the rule — launchers round their
    prompt/cap lengths via ``seq_shards`` below."""
    axes = mesh_axes(mesh)
    ba = batch_axes(mesh)
    nb = int(np.prod([axes[a] for a in ba]))
    if batch % nb == 0:
        return ba, ("model",)
    return (), tuple(mesh.axis_names)


def seq_shards(mesh, batch: int) -> int:
    """Sequence-shard count ``make_layout`` will pick for this
    (mesh, batch) — prompt/cap lengths must be multiples of it."""
    axes = mesh_axes(mesh)
    _, seq = _layout_axes(mesh, batch)
    return int(np.prod([axes[a] for a in seq]))


def make_layout(cfg: ModelConfig, mesh, batch: int, cap: int,
                hp: ServeHParams, prefill_len: int | None = None,
                placement: str = "aligned") -> ServeLayout:
    axes = mesh_axes(mesh)
    ba, seq = _layout_axes(mesh, batch)
    n_seq = int(np.prod([axes[a] for a in seq]))
    n0 = cap if prefill_len is None else prefill_len
    assert cap % n_seq == 0 and n0 % n_seq == 0 and n0 <= cap, (cap, n0, n_seq)
    assert placement in ("aligned", "rr"), placement
    cap_l = cap // n_seq
    L = max(1, int(n0 // (hp.means_cr * n_seq)))
    L = min(L, n0 // n_seq)
    return ServeLayout(ba, seq, n_seq, cap, cap_l, n0, L, placement)


def _paged_placement(hp: ServeHParams, paging) -> str:
    """Paged exact mode stores round-robin so pages cover contiguous
    position blocks (prefix sharing); paged prism keeps the aligned
    placement the Segment-Means shard ownership is defined over."""
    return "rr" if (paging is not None
                    and hp.decode_mode == "exact") else "aligned"


def grow_cache(cache, lay_from: ServeLayout, lay_to: ServeLayout):
    """Deprecated shim — use ``KVCache.grow_from`` (the engine's single
    cache-lifecycle object, built by ``make_kv_cache``).  Kept for the
    legacy padded admission tests; delegates to
    ``runtime.paging.grow_rows``."""
    return _paging.grow_rows(cache, lay_from, lay_to)


def insert_cache_row(dst, src, src_row, dst_row):
    """Deprecated shim — use ``KVCache.insert_row``.  Kept for the
    legacy padded admission tests; delegates to
    ``runtime.paging.splice_row`` (same semantics: a batch-dim splice
    of one cache row; jit with ``donate_argnums=(0,)``)."""
    return _paging.splice_row(dst, src, src_row, dst_row)


def reset_cache_row(cache, row):
    """Deprecated shim — use ``KVCache.reset_row``.  Delegates to
    ``runtime.paging.zero_row``."""
    return _paging.zero_row(cache, row)


# --------------------------------------------------------------------------
# cache pytree (+ shardings / ShapeDtypeStructs for the dry-run)
# --------------------------------------------------------------------------

def layer_cache_shape(cfg: ModelConfig, kind: str, lay: ServeLayout,
                      batch: int, hp: ServeHParams, dtype,
                      paging: PagedLayout | None = None):
    hkv, hd = cfg.n_kv_heads, cfg.hd
    d_in = cfg.d_model * cfg.ssm_expand
    if kind in ("attn", "moe", "shared_attn"):
        if paging is not None:
            # paged pool: a page gangs ``page_cols`` columns on every
            # seq shard (global dim 1 = page_cols·n_seq, sharded over
            # the seq axes exactly like the dense rows); the batch dim
            # is GONE — requests own page lists, not rows.  Prism's
            # Segment-Means running state rides in its own state-page
            # pool, one row per active request via ``state_map``.
            c = {"k": ((paging.n_pages, paging.pool_cap, hkv, hd), dtype),
                 "v": ((paging.n_pages, paging.pool_cap, hkv, hd), dtype)}
            if hp.decode_mode == "prism":
                m = lay.n_seq * lay.L
                s = paging.n_state_pages
                c["kz"] = ((s, m, hkv, hd), dtype)
                c["vz"] = ((s, m, hkv, hd), dtype)
                c["gz"] = ((s, m), jnp.float32)
                c["zsum"] = ((s, m, cfg.d_model), jnp.float32)
            return c
        # GLOBAL shapes (jit-level inputs); sharded over seq -> (B, cap_l)
        c = {"k": ((batch, lay.cap, hkv, hd), dtype),
             "v": ((batch, lay.cap, hkv, hd), dtype)}
        if hp.decode_mode == "prism":
            m = lay.n_seq * lay.L
            c["kz"] = ((batch, m, hkv, hd), dtype)
            c["vz"] = ((batch, m, hkv, hd), dtype)
            # per-request means-column repeat counts (the g of Eq. 14:
            # how many REAL tokens each kz/vz column averages; 0 = dead)
            # and the running per-segment sums of the block-input
            # activations that chunked prefill accumulates kz/vz from.
            c["gz"] = ((batch, m), jnp.float32)
            c["zsum"] = ((batch, m, cfg.d_model), jnp.float32)
        return c
    if paging is not None:
        raise ValueError(
            f"paged caches support position-addressed attention kinds "
            f"only (got block kind {kind!r})")
    if kind == "attn_local":
        w = min(cfg.window or lay.cap, lay.cap)
        return {"k": ((batch, w, hkv, hd), dtype),
                "v": ((batch, w, hkv, hd), dtype)}
    if kind == "mlstm":
        hdm = d_in // cfg.n_ssm_heads
        return {"s": ((batch, cfg.n_ssm_heads, hdm, hdm + 1), jnp.float32)}
    if kind == "slstm":
        return {"s": ((batch, 3, cfg.n_ssm_heads,
                       cfg.d_model // cfg.n_ssm_heads), jnp.float32)}
    if kind == "mamba":
        hdm = d_in // cfg.n_ssm_heads
        return {"s": ((batch, cfg.n_ssm_heads, cfg.ssm_state, hdm),
                      jnp.float32),
                "tail": ((batch, cfg.ssm_conv - 1, d_in), dtype)}
    raise ValueError(kind)


def layer_cache_spec(kind: str, lay: ServeLayout, hp: ServeHParams,
                     paging: PagedLayout | None = None):
    b = lay.bspec
    if kind in ("attn", "moe", "shared_attn"):
        if paging is not None:
            # pool pages replicated over the batch axes (every batch
            # replica computes identical writes), sharded over seq
            s = {"k": P(None, lay.seq_axes), "v": P(None, lay.seq_axes)}
            if hp.decode_mode == "prism":
                s["kz"] = P(None)
                s["vz"] = P(None)
                s["gz"] = P(None)
                s["zsum"] = P(None)
            return s
        s = {"k": P(b, lay.seq_axes), "v": P(b, lay.seq_axes)}
        if hp.decode_mode == "prism":
            s["kz"] = P(b)
            s["vz"] = P(b)
            s["gz"] = P(b)
            s["zsum"] = P(b)
        return s
    if kind == "attn_local":
        return {"k": P(b), "v": P(b)}
    if kind in ("mlstm", "slstm"):
        return {"s": P(b)}
    if kind == "mamba":
        return {"s": P(b), "tail": P(b)}
    raise ValueError(kind)


def cache_shapes(cfg: ModelConfig, lay: ServeLayout, batch: int,
                 hp: ServeHParams, dtype=jnp.float32,
                 paging: PagedLayout | None = None):
    """ShapeDtypeStruct pytree (dry-run input stand-in; no allocation).
    Mirrors the stacked parameter layout: {'scan': [u stacked trees with
    leading n_units], 'tail': [...]}."""
    u, n_units, _ = cfg.scan_split
    kinds = cfg.block_kinds

    def one(kind, lead=None):
        shapes = layer_cache_shape(cfg, kind, lay, batch, hp, dtype,
                                   paging)
        return {k: jax.ShapeDtypeStruct(
            ((lead,) + sh) if lead else sh, dt)
            for k, (sh, dt) in shapes.items()}
    return {"scan": [one(kinds[j], n_units) for j in range(u)],
            "tail": [one(kinds[n_units * u + t])
                     for t in range(len(kinds) - n_units * u)]}


def cache_specs(cfg: ModelConfig, lay: ServeLayout, hp: ServeHParams,
                paging: PagedLayout | None = None):
    u, n_units, _ = cfg.scan_split
    kinds = cfg.block_kinds

    def one(kind, stacked):
        s = layer_cache_spec(kind, lay, hp, paging)
        if stacked:
            s = {k: P(*((None,) + tuple(v))) for k, v in s.items()}
        return s
    return {"scan": [one(kinds[j], True) for j in range(u)],
            "tail": [one(kinds[n_units * u + t], False)
                     for t in range(len(kinds) - n_units * u)]}


def init_cache(cfg: ModelConfig, lay: ServeLayout, batch: int,
               hp: ServeHParams, dtype=jnp.float32,
               paging: PagedLayout | None = None):
    """Zero-filled global-shape cache (host-mesh tests / examples)."""
    shapes = cache_shapes(cfg, lay, batch, hp, dtype, paging)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def make_kv_cache(cfg: ModelConfig, mesh, lay: ServeLayout, batch: int,
                  hp: ServeHParams, *, paging: PagedLayout | None = None,
                  prefix_cache: bool = False,
                  dtype=jnp.float32) -> KVCache:
    """Build the engine's single cache object: zero-filled device
    storage placed under the right shardings, wrapped in a ``KVCache``
    (paged: pool + ``PageTable`` [+ ``PrefixCache``] and the
    alloc/append/fork/free lifecycle; dense: the legacy rowset with
    ``grow_from``/``insert_row``/``reset_row`` replacing the old free
    functions)."""
    specs = cache_specs(cfg, lay, hp, paging)
    sh = jax.tree.map(functools.partial(NamedSharding, mesh), specs)
    storage = jax.device_put(init_cache(cfg, lay, batch, hp, dtype,
                                        paging), sh)
    kv = KVCache(storage=storage, layout=lay, paging=paging, sharding=sh)
    if paging is not None and prefix_cache:
        kv.prefix = PrefixCache(kv.table)
    return kv


def make_kv_store(capacity_bytes: int | None = None):
    """Host offload tier companion to ``make_kv_cache``: an LRU
    ``KVStore`` for spilled requests.  ``KVCache.spill`` gathers a
    slot's pages (+ prism kz/vz/gz/zsum state row) device→host in one
    jitted gather and hands the refcounts back to the page table;
    ``KVCache.plan_restore`` re-enters the normal ``plan`` → ``reserve``
    → ``bind`` admission path with the covered-token count taken from
    the store, and ``KVCache.restore`` injects the payload into the
    freshly bound pages — decode then resumes bit-identically in both
    decode modes (page/state maps hide the physical relocation)."""
    from .offload import KVStore
    return KVStore(capacity_bytes=capacity_bytes)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

def _write_slot(cache_kv, new_row, slot, owner):
    """Write (B,1,Hkv,hd) rows into per-request cache slots.

    ``slot`` and ``owner`` are (B,) — every batch row carries its own
    decode depth, so a continuous-batching engine can hold requests at
    different positions in the same cache.  Rows whose ``owner`` is
    False (wrong shard, or idle slot with pos < 0) get their current
    column written back unchanged — an O(B) scatter, not a full-cache
    select, so the write cost stays independent of the cache capacity.
    """
    rows = jnp.arange(cache_kv.shape[0])
    cols = jnp.clip(slot, 0, cache_kv.shape[1] - 1)
    cur = cache_kv[rows, cols]                            # (B, Hkv, hd)
    upd = jnp.where(owner[:, None, None],
                    new_row[:, 0].astype(cache_kv.dtype), cur)
    return cache_kv.at[rows, cols].set(upd)


def _write_chunk(cache_kv, new_rows, slot, owner):
    """Scatter a prefill chunk's (B,C,Hkv,hd) rows into per-request
    cache slots at runtime offsets.  ``slot``/``owner`` are (B,C) —
    every chunk token lands at its own column of its own shard.
    Non-owner entries (wrong shard, dead token) are routed to an
    out-of-range column and dropped by the scatter, so duplicate
    in-range indices never occur (a request's chunk positions are
    distinct) and the write stays O(B·C), independent of capacity."""
    b, cap_l = cache_kv.shape[:2]
    rows = jnp.arange(b)[:, None]
    cols = jnp.where(owner, slot, cap_l)                  # OOB -> dropped
    return cache_kv.at[rows, cols].set(
        new_rows.astype(cache_kv.dtype), mode="drop")


def _write_packed(cache_kv, new_rows, row, col, ok):
    """Scatter a packed tick's (T,Hkv,hd) K/V rows into the
    (B,cap_l,Hkv,hd) cache at per-token (batch row, column) addresses.
    Tokens whose ``ok`` is False (dead entry, wrong sequence shard,
    wrong batch shard) are routed to an out-of-range column and dropped
    by the scatter.  In-range duplicates never occur — the engine packs
    each (request, position) at most once per tick — so the write stays
    O(T), independent of both the slot count and the capacity."""
    b, cap_l = cache_kv.shape[:2]
    r = jnp.clip(row, 0, b - 1)
    c = jnp.where(ok, col, cap_l)                         # OOB -> dropped
    return cache_kv.at[r, c].set(new_rows.astype(cache_kv.dtype),
                                 mode="drop")


def _gather_pages(pool, pages):
    """Reassemble virtual cache rows from the page pool: ``pool``
    (n_pages, page_cols, ...) LOCAL shard, ``pages`` (R, ppr) physical
    page ids per logical page slot -> (R, ppr·page_cols, ...) —
    logical column ``j`` of row ``r`` is page ``pages[r, j // pc]``,
    offset ``j % pc``.  Unmapped slots (id < 0) gather page 0; callers
    mask them out of ``valid`` (they are never owned positions).  This
    is the one extra level of indirection every paged step pays —
    the paged generalization of the packed step's per-token row
    gather."""
    pc = pool.shape[1]
    g = jnp.take(pool, jnp.clip(pages, 0, pool.shape[0] - 1), axis=0)
    return g.reshape(pages.shape[0], pages.shape[1] * pc,
                     *pool.shape[2:])


def _write_pool(pool, rows, page, poff, ok):
    """Scatter per-item (Hkv, hd) rows into the page pool at
    (physical page, in-page offset) addresses.  Items with ``ok``
    False or an unmapped page route to an out-of-range offset and are
    dropped; in-range duplicates never occur (each page has exactly
    one writer — shared prefix pages are never in any write window).
    O(items), independent of pool size."""
    n_pages, pc = pool.shape[:2]
    pg = jnp.clip(page, 0, n_pages - 1)
    po = jnp.where(ok & (page >= 0), poff, pc)            # OOB -> dropped
    return pool.at[pg, po].set(rows.astype(pool.dtype), mode="drop")


def decode_attention(q, k, v, valid, axes, scale, *, gz=None, kz=None,
                     vz=None, owner=None, mode="exact", backend="auto"):
    """Single entry point for per-token decode attention — every decode
    path (exact flash-decode, prism means decode, the TP variant) routes
    here.  Partial softmax stats (m, l, acc) over the LOCAL cache shard
    — plus, in prism mode, the means columns folded in via the ``+log g``
    bias, with no cache-sized concatenate on either backend — come from
    the Pallas kernel (``backend='pallas'``) or the two-pass jnp
    implementation (``'jnp'``; ``'auto'`` picks by platform).  The
    cross-shard combine is unchanged from ``flash_decode_combine`` /
    ``prism_decode_attention``, which remain below as the dense jnp
    test oracles.

    q (B,1,Hq,hd); k,v (B,M,Hkv,hd) local shard; valid (B,M) bool.
    Prism extras: gz (B,m) per-row means repeat counts (0 = dead
    column), kz/vz (B,m,Hkv,hd), owner (B,) bool, mode='prism'.
    """
    log_gz = log_repeats(gz) if kz is not None else None
    if use_pallas(backend):
        m_p, l_p, acc_p = flash_decode_stats(
            q, k, v, valid, log_gz, kz, vz, scale=scale,
            interpret=pallas_interpret())
    else:
        m_p, l_p, acc_p = decode_stats_reference(
            q, k, v, valid, log_gz, kz, vz, scale=scale)

    if mode == "prism":
        # scaling-aware softmax already folded into the stats; normalize
        # locally and select the owner's view (paper rule) via psum
        denom = jnp.maximum(l_p[:, :, 0, 0], 1e-30)       # (B,Hq)
        out = (acc_p / denom[:, None, :, None]).astype(v.dtype)
        if axes:
            sel = owner[:, None, None, None]
            out = lax.psum(jnp.where(sel, out, jnp.zeros_like(out)), axes)
        return out

    # exact: the flash-decoding pmax/psum stat combine
    return _combine_exact(m_p, l_p, acc_p, axes).astype(v.dtype)


def chunk_attention(q, k, v, valid, bias_self, k_new, v_new, axes, scale,
                    backend="auto"):
    """Exact attention for one prefill chunk — the multi-query sibling
    of ``decode_attention``.  Two disjoint column sets, two passes:

      * **prior columns** — everything this request laid down before
        the chunk (``valid (B,M)`` is col_pos < chunk offset, uniform
        over the chunk's queries), so the single-token flash-decode
        kernel applies verbatim with the C·Hq query heads folded into
        the GQA head axis (KV-head-major, preserving the grouping);
      * **the chunk itself** — the C just-projected K/V rows under a
        per-query causal bias (``bias_self (B,C,C)``), a tiny dense
        jnp pass (C ≪ cache capacity).

    The two stat triples merge associatively and the cross-shard
    pmax/psum combine keeps the result exact — chunked prefill is
    token-identical to the monolithic prefill and to sequential decode.

    q (B,C,Hq,hd); k,v (B,M,Hkv,hd) the local prefill-region shard;
    k_new,v_new (B,C,Hkv,hd).  Returns (B,C,Hq,hd)."""
    b, c, hq, hd = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    # fold queries KV-head-major: index (kv, c, g) -> kernel's GQA map
    # (head i attends kv head i // (c·grp)) stays correct
    qf = (q.reshape(b, c, hkv, grp, hd).swapaxes(1, 2)
          .reshape(b, 1, c * hq, hd))
    if use_pallas(backend):
        m1, l1, a1 = flash_decode_stats(qf, k, v, valid, scale=scale,
                                        interpret=pallas_interpret())
    else:
        m1, l1, a1 = decode_stats_reference(qf, k, v, valid, scale=scale)

    def unfold_stat(s):                       # (B, C·Hq, 1, 1)
        s = s.reshape(b, hkv, c, grp)
        return s.transpose(0, 1, 3, 2).reshape(b, hq, c)[..., None]

    def unfold_acc(a):                        # (B, 1, C·Hq, hd)
        a = a[:, 0].reshape(b, hkv, c, grp, hd)
        return a.transpose(0, 2, 1, 3, 4).reshape(b, c, hq, hd)

    stats_prior = (unfold_stat(m1), unfold_stat(l1), unfold_acc(a1))
    stats_self = chunk_softmax_stats(q, k_new, v_new, bias_self, scale)
    m_p, l_p, acc_p = merge_stats(stats_prior, stats_self)
    return _combine_exact(m_p, l_p, acc_p, axes).astype(v.dtype)


def _combine_exact(m_p, l_p, acc_p, axes):
    """Cross-shard flash-softmax stat combine: rescale each shard's
    (l, acc) to the global max, psum, normalize.  O(B·Hq·hd) traffic,
    independent of N.  Shards with no valid column (m = NEG) cancel via
    corr = 0; an all-shards-empty row lands on the 1e-30 clamp and
    yields a finite zero.  Shape-generic over the query count Nq —
    m, l (B,Hq,Nq,1), acc (B,Nq,Hq,hd) — so the chunked-prefill pass
    combines a whole chunk of queries with the same primitive."""
    m_g = lax.pmax(m_p, axes) if axes else m_p
    corr = jnp.exp(m_p - m_g)                             # (B,Hq,Nq,1)
    l_c = l_p * corr
    acc_c = acc_p * jnp.swapaxes(corr[..., 0], 1, 2)[..., None].astype(
        acc_p.dtype)
    if axes:
        l_c = lax.psum(l_c, axes)
        acc_c = lax.psum(acc_c, axes)
    denom = jnp.maximum(l_c[..., 0], 1e-30)               # (B,Hq,Nq)
    return acc_c / jnp.swapaxes(denom, 1, 2)[..., None].astype(acc_c.dtype)


def flash_decode_combine(q, k, v, valid, axes, scale):
    """Exact distributed flash-decoding, dense jnp form — materializes
    the (B,Hq,1,M) score tensor, so it now serves as the TEST ORACLE for
    ``decode_attention`` (the runtime routes through the kernel/two-pass
    path above).  q (B,1,Hq,hd); k,v are LOCAL cache shards
    (B,M,Hkv,hd); ``valid`` (B,M) bool (per-request column visibility).
    Combines partial softmax stats over ``axes`` — O(B·Hq·hd) traffic,
    independent of N."""
    s = _gqa_logits(q, k, scale)                          # (B,Hq,1,M)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m_p = jnp.max(s, axis=-1, keepdims=True)              # (B,Hq,1,1)
    e = jnp.exp(s - m_p)
    l_p = jnp.sum(e, axis=-1, keepdims=True)              # (B,Hq,1,1)
    acc_p = _gqa_output(e.astype(v.dtype), v)             # (B,1,Hq,hd)
    return _combine_exact(m_p, l_p, acc_p, axes)


def prism_decode_attention(q, k_loc, v_loc, kz, vz, valid, gz, owner,
                           axes, scale):
    """Paper-faithful decode, dense jnp form — TEST ORACLE for
    ``decode_attention(mode='prism')``; the runtime no longer pays this
    per-step cache-sized concatenate.  Exact local columns (g=1 where
    valid) plus remote Segment-Means columns (g = segment sizes; 0 for
    own shard), scaling-aware softmax, owner's view selected via masked
    psum.  ``valid`` (B,M_loc), ``gz`` (B,m) and ``owner`` (B,) are
    per-request — slots decode at independent depths."""
    k_all = jnp.concatenate([k_loc, kz.astype(k_loc.dtype)], axis=1)
    v_all = jnp.concatenate([v_loc, vz.astype(v_loc.dtype)], axis=1)
    g = jnp.concatenate([valid.astype(jnp.float32), gz], axis=1)
    s = _gqa_logits(q, k_all, scale)                      # (B,Hq,1,M)
    s = s + log_repeats(g)[:, None, None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    w = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    out = _gqa_output(w.astype(v_all.dtype), v_all)       # (B,1,Hq,hd)
    if axes:
        sel = owner[:, None, None, None]
        out = lax.psum(jnp.where(sel, out, jnp.zeros_like(out)), axes)
    return out


# --------------------------------------------------------------------------
# per-layer decode dispatch
# --------------------------------------------------------------------------

def _seq_index(seq_axes):
    idx = lax.axis_index(seq_axes[0])
    for a in seq_axes[1:]:
        idx = idx * axis_size(a) + lax.axis_index(a)
    return idx


def _batch_index(ba):
    """Linearized shard index over the batch mesh axes (0 when the
    batch is replicated) — the packed step needs it to map a global
    slot id to this shard's local cache row."""
    if not ba:
        return jnp.int32(0)
    return _seq_index(ba)


def _means_meta(lay: ServeLayout):
    """Static (lo, hi, mid, sizes, shard_of) for the means-cache columns,
    shard-major over the PREFILL region — matching both the
    ShardedPrismContext._augment_prism ordering and the prefill capture."""
    n0 = lay.n_loc0
    lo0, hi0 = segment_bounds(n0, lay.L)
    sizes = segment_sizes(n0, lay.L).astype(np.float32)
    offs = np.repeat(np.arange(lay.n_seq) * n0, lay.L)
    lo = np.tile(lo0, lay.n_seq) + offs
    hi = np.tile(hi0, lay.n_seq) + offs
    shard_of = np.repeat(np.arange(lay.n_seq), lay.L)
    return lo, hi, (lo + hi) / 2.0, np.tile(sizes, lay.n_seq), shard_of


def _decode_cols(lay: ServeLayout, idx, pos):
    """(write_slot (B,), owner (B,), col_pos (cap_l,)) under the
    layout's placement (see ServeLayout).  ``pos`` is the (B,)
    per-request position vector; idle slots pass pos = -1, which lands
    owner = False on every shard (no write).  ``col_pos`` maps shard
    slots to global positions and is position-independent."""
    if lay.placement == "rr":
        # pure round-robin: position p -> shard p % n_seq, column
        # p // n_seq.  pos = -1 floors to slot -1 (owner False).
        slot = pos // lay.n_seq
        wr_shard = pos % lay.n_seq
        owner = ((wr_shard == idx) & (slot >= 0) & (slot < lay.cap_l)
                 & (pos >= 0))
        col_pos = jnp.arange(lay.cap_l) * lay.n_seq + idx
        return slot, owner, col_pos
    n0, n_loc0 = lay.prefill_len, lay.n_loc0
    extra = pos - n0
    slot = jnp.where(extra >= 0,
                     n_loc0 + extra // lay.n_seq,
                     pos - idx * n_loc0)
    wr_shard = jnp.where(extra >= 0, extra % lay.n_seq,
                         jnp.clip(pos // jnp.maximum(n_loc0, 1),
                                  0, lay.n_seq - 1))
    owner = (wr_shard == idx) & (slot >= 0) & (slot < lay.cap_l)
    j = jnp.arange(lay.cap_l)
    col_pos = jnp.where(
        j < n_loc0,
        idx * n_loc0 + j,
        n0 + (j - n_loc0) * lay.n_seq + idx)
    return slot, owner, col_pos


def attn_decode(p, spec: AttnSpec, cfg: ModelConfig, x, c, pos,
                lay: ServeLayout, hp: ServeHParams, *, local: bool,
                page_map=None, state_map=None, degraded=None):
    """x (B,1,D) replicated over seq axes, pos (B,) per-request positions
    (-1 = idle slot) -> (out (B,1,D), new layer cache).

    Paged mode (``page_map`` (B, ppr) set): the layer cache is the
    page pool; each row's virtual cache row is gathered through its
    page list, the new K/V row scatters to its (page, offset) address,
    and in prism mode the per-request means state is read through
    ``state_map`` (B,) from the state-page pool.  Everything is
    replicated over the batch axes (identical writes on every
    replica), so the attention combine still runs over the sequence
    axes only.

    ``degraded = (lost, rep)`` arms the shard-loss path (engine
    degraded mode, ``runtime/replica.py``): ``lost`` is the (n_seq,)
    float mask of unreadable sequence shards.  On a lost shard every
    exact column is masked out of the stat combine and cache writes
    are dropped; the shard's positions are served instead by
    Segment-Means columns through the existing ``+log g`` bias path —
    in exact mode from the standby replica ``rep`` ({"kz" (B,m,Hkv,hd),
    "vz", "gz" (B,m)}, served ONLY by the lost shard so the psum
    counts each mean once — in the simulation its device lanes stand
    in for the neighbor that would host the replica), in prism mode
    from the means already replicated in the cache (the lost shard's
    own-shard gate simply opens, ``rep`` rides as None).  Tokens stay
    finite with PRISM-bounded quality loss instead of failing."""
    xn = norm(p["ln1"], x, cfg.norm_kind)
    rp = pos[:, None]                          # (B,1) row positions
    q = attn_project_q(p["attn"], spec, xn, rp)
    k_new, v_new = attn_project_kv(p["attn"], spec, xn, rp)
    scale = spec.head_dim ** -0.5

    if local:                                  # ring window cache, replicated
        w = c["k"].shape[1]
        alive = pos >= 0
        k_c = _write_slot(c["k"], k_new, pos % w, alive)
        v_c = _write_slot(c["v"], v_new, pos % w, alive)
        j = jnp.arange(w)
        # ring slot -> global position, per request
        col_pos = pos[:, None] - ((pos[:, None] - j[None, :]) % w)
        valid = col_pos >= 0
        if spec.window:
            valid &= col_pos > pos[:, None] - spec.window
        out = decode_attention(q, k_c, v_c, valid, (), scale,
                               backend=hp.backend)
        new_c = dict(c, k=k_c, v=v_c)
    else:
        idx = _seq_index(lay.seq_axes)
        slot, owner, col_pos = _decode_cols(lay, idx, pos)
        wr_ok, lostf, rep = owner, None, None
        if degraded is not None:
            lost_vec, rep = degraded
            lostf = jnp.take(lost_vec, idx) > 0    # this shard is dead
            wr_ok = owner & ~lostf                 # writes dropped there
        if page_map is not None:
            pc = c["k"].shape[1]
            colc = jnp.clip(slot, 0, lay.cap_l - 1)
            pg = jnp.take_along_axis(
                page_map, (colc // pc)[:, None], axis=1)[:, 0]
            k_pool = _write_pool(c["k"], k_new[:, 0], pg, colc % pc,
                                 wr_ok)
            v_pool = _write_pool(c["v"], v_new[:, 0], pg, colc % pc,
                                 wr_ok)
            k_c = _gather_pages(k_pool, page_map)
            v_c = _gather_pages(v_pool, page_map)
            mapped = jnp.repeat(page_map >= 0, pc, axis=1)
            valid = mapped & (col_pos[None, :] <= pos[:, None])
        else:
            k_c = _write_slot(c["k"], k_new, slot, wr_ok)
            v_c = _write_slot(c["v"], v_new, slot, wr_ok)
            valid = col_pos[None, :] <= pos[:, None]
        if lostf is not None:
            # the lost shard's exact columns leave the stat combine
            valid = valid & ~lostf
        if hp.decode_mode == "prism" and "kz" in c:
            # per-request repeat counts ride in the cache (written by
            # the prefill that captured kz/vz, so they count REAL
            # columns only — a short prompt's partially-filled segments
            # carry their true token count, never pad columns).  The
            # own shard is masked out (its columns are served exact),
            # and a mean is visible only once every position it covers
            # ([lo, lo+gz), prefix-contiguous by construction) is in
            # the query's past — for chunked captures that always
            # holds, for the legacy padded flush (gz = full sizes) it
            # reduces to the old ``hi <= pos`` causal gating.
            lo, _, _, _, shard_of = _means_meta(lay)
            if state_map is not None:
                sr = jnp.clip(state_map, 0, c["gz"].shape[0] - 1)
                cnt = jnp.take(c["gz"], sr, axis=0)
                kz_r = jnp.take(c["kz"], sr, axis=0)
                vz_r = jnp.take(c["vz"], sr, axis=0)
            else:
                cnt, kz_r, vz_r = c["gz"], c["kz"], c["vz"]
            served = jnp.asarray(shard_of)[None, :] != idx
            if lostf is not None:
                # the state pool is replicated across the seq shards,
                # so the means ARE the standby: the lost shard's own
                # columns open up everywhere (including on itself)
                lost_col = jnp.take(lost_vec, jnp.asarray(shard_of)) > 0
                served = served | lost_col[None, :]
            gz = jnp.where(
                served
                & (jnp.asarray(lo)[None, :] + cnt <= pos[:, None] + 1),
                cnt, 0.0)
            out = decode_attention(
                q, k_c, v_c, valid, lay.seq_axes, scale,
                gz=gz, kz=kz_r, vz=vz_r, owner=owner,
                mode="prism", backend=hp.backend)
        elif rep is not None:
            # exact degraded: substitute the lost shard's columns with
            # its standby Segment-Means replica, served only on the
            # lost shard itself so the exact psum counts each mean once
            shard_of_r = np.repeat(np.arange(lay.n_seq), lay.L)
            lost_col = jnp.take(lost_vec, jnp.asarray(shard_of_r)) > 0
            serve = lost_col[None, :] & \
                (jnp.asarray(shard_of_r)[None, :] == idx)
            gz_d = jnp.where(serve, rep["gz"], 0.0)
            out = decode_attention(
                q, k_c, v_c, valid, lay.seq_axes, scale,
                gz=gz_d, kz=rep["kz"].astype(k_c.dtype),
                vz=rep["vz"].astype(v_c.dtype),
                mode="exact", backend=hp.backend)
        else:
            out = decode_attention(q, k_c, v_c, valid, lay.seq_axes,
                                   scale, backend=hp.backend)
        if page_map is not None:
            new_c = dict(c, k=k_pool, v=v_pool)
        else:
            new_c = dict(c, k=k_c, v=v_c)

    o = attn_output(p["attn"], out)
    if cfg.parallel_block:
        o = o + mlp(p["mlp"], xn, cfg.mlp_kind)
    return o, new_c


def mlp_tp(p, x, kind: str, *, tp_ffn: bool):
    """Feed-forward with column-parallel up/gate and row-parallel down
    (weights stay sharded over 'model'; one psum of (B,1,D))."""
    y = mlp(p, x, kind)
    return lax.psum(y, "model") if tp_ffn else y


def attn_decode_tp(p, spec: AttnSpec, cfg: ModelConfig, x, c, pos,
                   lay: ServeLayout, hp: ServeHParams, *,
                   attn_tp: bool, ffn_tp: bool):
    """Tensor-parallel single-token attention (§Perf H1).

    wq column-parallel (this shard computes Hq/tp heads, then a tiny
    head all-gather so every shard can attend over its LOCAL cache shard
    with ALL heads — flash-decoding needs the full head dim against the
    sequence shard), wo row-parallel (each shard consumes its own head
    slice; psum of (B,1,D)).  wk/wv are replicated (GQA keeps them
    small).  Per-token parameter traffic: ZERO — the baseline's
    per-layer FSDP gather (the whole weight matrix per token) becomes
    one activation psum.
    """
    tp = axis_size("model")
    xn = norm(p["ln1"], x, cfg.norm_kind)
    rp = pos[:, None]                          # (B,1) row positions
    b = x.shape[0]

    if attn_tp:
        hq_loc = spec.n_heads // tp
        q_loc = dense(p["attn"]["wq"], xn).reshape(b, 1, hq_loc,
                                                   spec.head_dim)
        if spec.qk_norm:
            q_loc = norm(p["attn"]["qnorm"], q_loc)
        if spec.rope_theta is not None:
            from ..models.layers import rope
            q_loc = rope(q_loc, rp, theta=spec.rope_theta)
        q = lax.all_gather(q_loc, "model", axis=2, tiled=True)
    else:
        q = attn_project_q(p["attn"], spec, xn, rp)
    k_new, v_new = attn_project_kv(p["attn"], spec, xn, rp)
    scale = spec.head_dim ** -0.5

    idx = _seq_index(lay.seq_axes)
    slot, owner, col_pos = _decode_cols(lay, idx, pos)
    k_c = _write_slot(c["k"], k_new, slot, owner)
    v_c = _write_slot(c["v"], v_new, slot, owner)
    valid = col_pos[None, :] <= pos[:, None]
    out = decode_attention(q, k_c, v_c, valid, lay.seq_axes, scale,
                           backend=hp.backend)
    new_c = dict(c, k=k_c, v=v_c)

    if attn_tp:
        midx = lax.axis_index("model")
        hq_loc = spec.n_heads // tp
        out_loc = lax.dynamic_slice_in_dim(out, midx * hq_loc, hq_loc,
                                           axis=2)
        o = dense(p["attn"]["wo"], out_loc.reshape(b, 1, -1))
        o = lax.psum(o, "model")
    else:
        o = attn_output(p["attn"], out)
    if cfg.parallel_block:
        o = o + mlp_tp(p["mlp"], xn, cfg.mlp_kind, tp_ffn=ffn_tp)
    return o, new_c


class DecodeMoeCtx:
    """Expert exchange for single-token decode: all_to_all over 'model'
    (expert parallelism); with ``tp`` the per-expert d_ff dim is sharded
    over 'data' and the down-projection partials are psum'd (expert-TP —
    no per-token expert-weight gather, ever)."""

    def __init__(self, tp: bool = False):
        self.tp = tp

    def expert_exchange(self, buf):
        out = lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                             tiled=True)
        if self.tp:
            # expert-TP: the d_ff slices live across 'data', but so do the
            # tokens — share tokens first (activation-sized), compute the
            # dff partials everywhere, psum in expert_reduce, slice back.
            out = lax.all_gather(out, "data", axis=1, tiled=True)

        def undo(y):
            if self.tp:
                d = lax.axis_index("data")
                s = y.shape[1] // axis_size("data")
                y = lax.dynamic_slice_in_dim(y, d * s, s, axis=1)
            return lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                                  tiled=True)
        return out, undo

    def expert_reduce(self, y):
        return lax.psum(y, "data") if self.tp else y

    def ffn_reduce(self, y):
        return lax.psum(y, "model") if self.tp else y


def block_decode(cfg: ModelConfig, kind: str, p, shared, x, c, pos,
                 lay: ServeLayout, hp: ServeHParams,
                 tp_flags=(False, False), page_map=None, state_map=None,
                 degraded=None):
    """One residual block, single-token decode.  Returns (x, new_cache).
    ``degraded`` (see ``attn_decode``) arms the shard-loss substitution
    on the sequence-sharded attention kinds; ring-window and SSM state
    is replicated over the sequence axes and unaffected."""
    attn_tp, ffn_tp = tp_flags
    use_tp = hp.decode_tp and kind in ("attn", "moe", "shared_attn")

    def ffn(pp, xx):
        if hp.decode_tp and ffn_tp:
            return mlp_tp(pp, xx, cfg.mlp_kind, tp_ffn=True)
        return mlp(pp, xx, cfg.mlp_kind)

    if kind in ("attn", "attn_local", "moe"):
        spec = T.attn_spec(cfg, kind)
        if use_tp:
            o, c = attn_decode_tp(p, spec, cfg, x, c, pos, lay, hp,
                                  attn_tp=attn_tp, ffn_tp=ffn_tp)
        else:
            o, c = attn_decode(p, spec, cfg, x, c, pos, lay, hp,
                               local=(kind == "attn_local"),
                               page_map=page_map, state_map=state_map,
                               degraded=(None if kind == "attn_local"
                                         else degraded))
        x = x + o
        if cfg.parallel_block:
            return x, c
        if kind == "moe":
            y, _ = moe_apply(p["moe"], norm(p["ln2"], x, cfg.norm_kind),
                             cfg, DecodeMoeCtx(tp=hp.decode_tp))
            x = x + y
        elif cfg.d_ff:
            x = x + ffn(p["mlp"], norm(p["ln2"], x, cfg.norm_kind))
        return x, c
    if kind == "shared_attn":
        spec = T.attn_spec(cfg, "attn")
        if use_tp:
            o, c = attn_decode_tp(shared, spec, cfg, x, c, pos, lay, hp,
                                  attn_tp=attn_tp, ffn_tp=ffn_tp)
        else:
            o, c = attn_decode(shared, spec, cfg, x, c, pos, lay, hp,
                               local=False, page_map=page_map,
                               state_map=state_map, degraded=degraded)
        x = x + o
        x = x + ffn(shared["mlp"], norm(shared["ln2"], x, cfg.norm_kind))
        return x, c
    if kind == "mlstm":
        y, s = mlstm_decode(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                            c["s"], heads=cfg.n_ssm_heads)
        return x + y, dict(c, s=s)
    if kind == "slstm":
        y, s = slstm_decode(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                            c["s"], heads=cfg.n_ssm_heads)
        return x + y, dict(c, s=s)
    if kind == "mamba":
        y, cc = mamba2_decode(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                              c, heads=cfg.n_ssm_heads,
                              d_state=cfg.ssm_state, expand=cfg.ssm_expand,
                              conv=cfg.ssm_conv)
        return x + y, cc
    raise ValueError(kind)


# --------------------------------------------------------------------------
# decode embedding / head
# --------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, rules, token, pos, *,
                 sharded_vocab):
    """token (B,T), pos (B,T) -> x (B,T,D), replicated over the
    sequence axes.  Positions are per request *and* per token (chunked
    prefill feeds T = chunk_len tokens at per-row offsets); dead
    entries (pos = -1) still embed but never reach the cache (owner
    masking in the attention layers)."""
    table = params["embed"]["table"]
    if sharded_vocab:
        v_loc = table.shape[0]
        vstart = lax.axis_index("model") * v_loc
        t = token - vstart
        ok = (t >= 0) & (t < v_loc)
        e = jnp.take(table, jnp.clip(t, 0, v_loc - 1), axis=0)
        x = lax.psum(jnp.where(ok[..., None], e, jnp.zeros_like(e)),
                     "model")
    else:
        table = gather_tree(params["embed"], rules["embed"])["table"]
        x = jnp.take(table, token, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos == "learned":
        tbl = gather_tree(params["pos_embed"], rules["pos_embed"])["table"]
        safe = jnp.clip(pos, 0, tbl.shape[0] - 1)
        x = x + jnp.take(tbl, safe, axis=0).astype(x.dtype)
    elif cfg.pos == "sincos":
        half = cfg.d_model // 2
        freq = jnp.exp(-np.log(10000.0)
                       * jnp.arange(half, dtype=jnp.float32) / half)
        ang = pos.astype(jnp.float32)[..., None] * freq    # (B,T,half)
        x = x + jnp.concatenate(
            [jnp.sin(ang), jnp.cos(ang)], -1).astype(x.dtype)
    return x


def embed_token(cfg: ModelConfig, params, rules, token, pos, *,
                sharded_vocab):
    """Single-token decode form: token (B,), pos (B,) -> x (B,1,D)."""
    return embed_tokens(cfg, params, rules, token[:, None], pos[:, None],
                        sharded_vocab=sharded_vocab)


# --------------------------------------------------------------------------
# serve (decode) step factory
# --------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, mesh, params, *,
                    batch: int, cap: int, prefill_len: int | None = None,
                    hp: ServeHParams = ServeHParams(),
                    paging: PagedLayout | None = None,
                    degraded: bool = False):
    """jitted (params, cache, token (B,), pos (B,)) -> (logits, cache).

    ``pos`` carries one position per batch row, so independent requests
    can decode at different depths in the same step (continuous
    batching).  Idle slots pass pos = -1: they compute garbage-but-
    finite logits and never write the cache (owner masking).  ``logits``
    is (B, V) — vocab-sharded over 'model' when the embedding table is
    (the returned lspec says which).

    With ``paging`` the cache is the page pool and the program takes
    two extra inputs ``(page_map (B, ppr), state_map (B,))`` — the
    per-slot physical page lists the host rebuilds each tick.  Token /
    pos vectors ride replicated (the pool is replicated over the batch
    axes; every replica computes identical writes), and logits come
    back replicated too.

    ``degraded=True`` builds the SHARD-LOSS variant the engine runs
    while a sequence shard is unreadable: the program takes one extra
    ``lost (n_seq,)`` float mask (replicated) and — in exact decode
    mode — a standby-replica tree ({"scan": [{kz,vz,gz} ...], "tail":
    [...]}, ``MeansReplica.assemble``'s output, replicated).  The lost
    shard's exact columns are masked out of the stat combine and its
    positions served from Segment-Means columns instead (see
    ``attn_decode``); cache writes to the lost shard are dropped.
    Requires the paged cache (the engine's degraded orchestration
    rides page-table bookkeeping).
    """
    lay = make_layout(cfg, mesh, batch, cap, hp, prefill_len,
                      _paged_placement(hp, paging))
    if degraded:
        assert paging is not None, \
            "degraded decode requires the paged cache"
    if paging is not None:
        assert not hp.decode_tp, "paged serving does not support decode_tp"
    if hp.decode_tp:
        from ..sharding.rules import decode_param_specs
        rules = decode_param_specs(params, mesh, cfg.vocab_size, cfg)
        ax = mesh_axes(mesh).get("model", 1)
        tp_flags = (cfg.n_heads % ax == 0 and not cfg.attn_bias
                    and (cfg.n_heads * cfg.hd) % ax == 0,
                    bool(cfg.d_ff) and cfg.d_ff % ax == 0
                    and not cfg.attn_bias)
    else:
        rules = param_specs(params, mesh, cfg.vocab_size)
        tp_flags = (False, False)
    pspecs = spec_tree(rules)
    cspecs = cache_specs(cfg, lay, hp, paging)
    vocab_sharded = (rules["embed"]["table"].kind == "vocab")
    shared_rules = rules.get("shared")

    u, n_units, _ = cfg.scan_split
    unit_kinds = cfg.block_kinds[:u]
    # degraded exact mode takes the standby-replica tree as one more
    # input; degraded prism reads its means straight from the
    # (replicated) cache state pool and needs only the lost mask
    with_rep = degraded and hp.decode_mode == "exact"

    def _rep_spec(kind):
        if kind not in ("attn", "moe", "shared_attn"):
            return {}
        return {k: P(None) for k in ("kz", "vz", "gz")}
    rep_specs = ({"scan": [_rep_spec(unit_kinds[j]) for j in range(u)],
                  "tail": [_rep_spec(cfg.block_kinds[n_units * u + t])
                           for t in range(len(cfg.block_kinds)
                                          - n_units * u)]}
                 if with_rep else None)

    def body_core(params_local, cache_local, token, pos, page_map,
                  state_map, lost=None, rep=None):
        trace_counts["serve_step_degraded" if degraded
                     else "serve_step"] += 1
        x = embed_token(cfg, params_local, rules, token, pos,
                        sharded_vocab=vocab_sharded)

        def unit_body(x, xs):
            if rep is not None:
                p_sl, c_sl, r_sl = xs
            else:
                (p_sl, c_sl), r_sl = xs, None
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            new = []
            for j, kind in enumerate(unit_kinds):
                p = gather_tree(p_sl[j], rules["scan"][j])
                deg = (None if lost is None else
                       (lost, r_sl[j] if (r_sl is not None and r_sl[j])
                        else None))
                x, nc = block_decode(cfg, kind, p, shared, x, c_sl[j],
                                     pos, lay, hp, tp_flags,
                                     page_map, state_map, degraded=deg)
                new.append(nc)
            return x, tuple(new)

        xs = (tuple(params_local["scan"]), tuple(cache_local["scan"]))
        if rep is not None:
            xs = xs + (tuple(rep["scan"]),)
        x, new_stacks = lax.scan(unit_body, x, xs)

        new_tail = []
        for t, tree in enumerate(params_local["tail"]):
            kind = cfg.block_kinds[n_units * u + t]
            p = gather_tree(tree, rules["tail"][t])
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            deg = None
            if lost is not None:
                rt = rep["tail"][t] if rep is not None else {}
                deg = (lost, rt if rt else None)
            x, nc = block_decode(cfg, kind, p, shared, x,
                                 cache_local["tail"][t], pos, lay, hp,
                                 tp_flags, page_map, state_map,
                                 degraded=deg)
            new_tail.append(nc)

        x = norm(params_local["final_norm"], x, cfg.norm_kind)
        table = output_table(params_local, cfg)
        logits = (x[:, 0] @ table.T.astype(x.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits, {"scan": list(new_stacks), "tail": new_tail}

    vspec = P(None) if paging is not None else P(lay.bspec)
    lspec = P(None if paging is not None else lay.bspec,
              "model" if vocab_sharded else None)
    extra = ()
    if degraded:
        extra = ((P(None), rep_specs) if with_rep else (P(None),))
    if paging is not None:
        body = body_core
        in_specs = (pspecs, cspecs, vspec, vspec, P(None), P(None)) \
            + extra
    else:
        def body(params_local, cache_local, token, pos, *deg):
            return body_core(params_local, cache_local, token, pos,
                             None, None, *deg)
        in_specs = (pspecs, cspecs, vspec, vspec) + extra
    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(lspec, cspecs),
        check_vma=False)

    sh = functools.partial(NamedSharding, mesh)
    jitted = jax.jit(
        body_sm,
        in_shardings=tuple(jax.tree.map(sh, s) for s in in_specs),
        out_shardings=(sh(lspec), jax.tree.map(sh, cspecs)),
        donate_argnums=(1,),
    )
    return jitted, lay, rules, lspec


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def _prefill_attention(q, k, v, akv, spec: AttnSpec, cfg: ModelConfig,
                       hp: ServeHParams):
    """Route the prefill attention through the Pallas flash kernel when
    the backend switch says so AND the augment carries positional
    (col_lo, col_hi) ranges — the kernel re-derives the mask in-VMEM.
    Views with extra mask structure (ring halo) stay on the jnp path."""
    if use_pallas(hp.backend) and akv.col_lo is not None:
        g = (akv.g if akv.g is not None
             else jnp.ones((k.shape[1],), jnp.float32))
        return prism_attention_op(
            q, k, v, g, akv.col_lo, akv.col_hi, akv.row_pos,
            causal=spec.causal, prefix_len=cfg.prefix_len,
            window=spec.window, interpret=pallas_interpret())
    return prism_attention(q, k, v, g=akv.g, mask=akv.mask,
                           block=cfg.attn_block)


def prefill_attn(p, spec: AttnSpec, cfg: ModelConfig, x, ctx, lay,
                 hp: ServeHParams, prism_augment: bool):
    """Attention sublayer that also captures this layer's decode cache."""
    xq, akv = ctx.augment(x, spec)
    xq_n = norm(p["ln1"], xq, cfg.norm_kind)
    xh_n = norm(p["ln1"], akv.x_hat, cfg.norm_kind)
    q = attn_project_q(p["attn"], spec, xq_n, akv.row_pos)
    k, v = attn_project_kv(p["attn"], spec, xh_n, akv.col_pos)
    o = _prefill_attention(q, k, v, akv, spec, cfg, hp)
    o = attn_output(p["attn"], o)
    if cfg.parallel_block:
        o = o + mlp(p["mlp"], xq_n, cfg.mlp_kind)

    n_loc = x.shape[1]
    if spec.window is not None:
        # window augment puts the local block LAST; ring cache = global
        # tail = last shard's last W rows, scattered to ring order.
        w = min(spec.window, lay.cap)
        assert n_loc >= w, "window larger than per-shard tokens"
        kw = ctx.last_shard(k[:, -w:])
        vw = ctx.last_shard(v[:, -w:])
        slots = np.arange(lay.cap - w, lay.cap) % w
        order = np.zeros(w, np.int64)
        order[slots] = np.arange(w)
        cache = {"k": jnp.take(kw, jnp.asarray(order), axis=1),
                 "v": jnp.take(vw, jnp.asarray(order), axis=1)}
        return ctx.finalize(o), cache

    if prism_augment:
        k_loc, v_loc = k[:, :n_loc], v[:, :n_loc]   # local block first
    else:                                           # voltage: full sequence
        start = ctx._index() * n_loc
        k_loc = lax.dynamic_slice_in_dim(k, start, n_loc, axis=1)
        v_loc = lax.dynamic_slice_in_dim(v, start, n_loc, axis=1)
    pad = lay.cap_l - n_loc
    if pad:
        k_loc = jnp.pad(k_loc, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_loc = jnp.pad(v_loc, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_loc, "v": v_loc}
    if hp.decode_mode == "prism":
        m = lay.n_seq * lay.L
        if prism_augment:
            # means columns sit right after the local block in x_hat
            cache["kz"] = k[:, n_loc:n_loc + m]
            cache["vz"] = v[:, n_loc:n_loc + m]
            z_all = akv.x_hat[:, n_loc:n_loc + m]
        else:                           # voltage prefill: compute means-KV
            if use_pallas(hp.backend):
                z = segment_means_op(x, L=lay.L,
                                     interpret=pallas_interpret())
            else:
                z = segment_means(x, lay.L)
            zg = ctx._gather(z)
            b = x.shape[0]
            z_all = jnp.moveaxis(zg, 0, 1).reshape(b, m, x.shape[-1])
            _, _, mid, _, _ = _means_meta(lay)
            kz, vz = attn_project_kv(
                p["attn"], spec, norm(p["ln1"], z_all, cfg.norm_kind),
                jnp.asarray(mid, jnp.float32))
            cache["kz"], cache["vz"] = kz, vz
        # monolithic prefill covers every position of [0, n0), so the
        # per-request repeat counts are the full static segment sizes
        # and the running sums are means × sizes (chunked prefill's
        # invariant: zsum / gz == the mean each kz/vz row was cut from)
        _, _, _, sizes, _ = _means_meta(lay)
        b = x.shape[0]
        cache["gz"] = jnp.broadcast_to(
            jnp.asarray(sizes, jnp.float32)[None], (b, m))
        cache["zsum"] = (z_all.astype(jnp.float32)
                         * jnp.asarray(sizes, jnp.float32)[None, :, None])
    return ctx.finalize(o), cache


def make_prefill_step(cfg: ModelConfig, mesh, params, prism: PrismConfig,
                      *, batch: int, n: int,
                      hp: ServeHParams = ServeHParams(),
                      cap: int | None = None):
    """jitted (params, batch_dict) -> (last-token logits, decode cache).

    ``batch_dict`` = {"tokens": (B, N)} (+ "embeds" for vlm/audio stubs).
    ``cap`` sizes the captured cache rows beyond the prompt (the
    padded-admission engine prefills straight into decode-capacity
    rows, so no grow step remains); default: rows sized to ``n``.
    """
    lay = make_layout(cfg, mesh, batch, n if cap is None else cap, hp,
                      prefill_len=n)
    rules = param_specs(params, mesh, cfg.vocab_size)
    pspecs = spec_tree(rules)
    cspecs = cache_specs(cfg, lay, hp)
    vocab_sharded = (rules["embed"]["table"].kind == "vocab")
    shared_rules = rules.get("shared")
    n_loc = n // lay.n_seq
    prism_cfg = prism.with_(P=lay.n_seq,
                            L=lay.L if hp.decode_mode == "prism"
                            else prism.L)
    prism_augment = prism_cfg.mode == "prism"

    def body(params_local, batch_local):
        trace_counts["prefill_step"] += 1
        ctx = ShardedPrismContext(
            prism_cfg, axis=lay.seq_axes[-1], n_shards=lay.n_seq,
            seq_shards=lay.seq_axes[:-1], prefix_len=cfg.prefix_len)
        tokens = batch_local.get("tokens")
        embeds = batch_local.get("embeds")
        start = ctx._index() * n_loc
        if tokens is not None:
            x = embed_vp(params_local["embed"]["table"], tokens,
                         sharded_vocab=vocab_sharded)
        else:
            fp = gather_tree(params_local["frontend_proj"],
                             rules["frontend_proj"])
            x = dense(fp, embeds)
        if cfg.arch_type == "vlm" and embeds is not None and tokens is not None:
            fp = gather_tree(params_local["frontend_proj"],
                             rules["frontend_proj"])
            fe = dense(fp, embeds)                 # (B, prefix, D) replicated
            pos = start + jnp.arange(n_loc)
            idx = jnp.clip(pos, 0, cfg.prefix_len - 1)
            fe_rows = jnp.take(fe, idx, axis=1)
            x = jnp.where((pos < cfg.prefix_len)[None, :, None], fe_rows, x)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.pos == "learned":
            tbl = gather_tree(params_local["pos_embed"],
                              rules["pos_embed"])["table"]
            x = x + lax.dynamic_slice_in_dim(tbl, start, n_loc
                                             ).astype(x.dtype)
        elif cfg.pos == "sincos":
            x = x + T.sincos_embed(n_loc, cfg.d_model, start).astype(x.dtype)

        u, n_units, _ = cfg.scan_split
        unit_kinds = cfg.block_kinds[:u]

        def one_block(kind, p, shared, x):
            if kind in ("attn", "attn_local", "moe", "shared_attn"):
                pp = shared if kind == "shared_attn" else p
                spec = T.attn_spec(cfg, "attn" if kind == "shared_attn"
                                   else kind)
                o, c = prefill_attn(pp, spec, cfg, x, ctx, lay, hp,
                                    prism_augment)
                x = x + o
                if kind == "moe" and not cfg.parallel_block:
                    y, _ = moe_apply(p["moe"],
                                     norm(p["ln2"], x, cfg.norm_kind),
                                     cfg, ctx)
                    x = x + y
                elif kind == "shared_attn":
                    x = x + mlp(shared["mlp"],
                                norm(shared["ln2"], x, cfg.norm_kind),
                                cfg.mlp_kind)
                elif cfg.d_ff and not cfg.parallel_block:
                    x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind),
                                cfg.mlp_kind)
                return x, c
            if kind == "mlstm":
                y, s = mlstm_apply(p["cell"],
                                   norm(p["ln"], x, cfg.norm_kind),
                                   heads=cfg.n_ssm_heads, ctx=ctx,
                                   chunk=hp.ssm_chunk, return_state=True)
                return x + y, {"s": s}
            if kind == "slstm":
                y, s = slstm_apply(p["cell"],
                                   norm(p["ln"], x, cfg.norm_kind),
                                   heads=cfg.n_ssm_heads, ctx=ctx,
                                   return_state=True)
                return x + y, {"s": s}
            if kind == "mamba":
                y, c = mamba2_apply(p["cell"],
                                    norm(p["ln"], x, cfg.norm_kind),
                                    heads=cfg.n_ssm_heads,
                                    d_state=cfg.ssm_state,
                                    expand=cfg.ssm_expand,
                                    conv=cfg.ssm_conv, ctx=ctx,
                                    chunk=hp.ssm_chunk, return_state=True)
                return x + y, c
            raise ValueError(kind)

        def unit_body(x, sliced):
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            cs = []
            for j, kind in enumerate(unit_kinds):
                p = gather_tree(sliced[j], rules["scan"][j])
                x, c = one_block(kind, p, shared, x)
                cs.append(c)
            return x, tuple(cs)

        x, cache_stacks = lax.scan(unit_body, x,
                                   tuple(params_local["scan"]))
        tail_caches = []
        for t, tree in enumerate(params_local["tail"]):
            kind = cfg.block_kinds[n_units * u + t]
            p = gather_tree(tree, rules["tail"][t])
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            x, c = one_block(kind, p, shared, x)
            tail_caches.append(c)

        x = norm(params_local["final_norm"], x, cfg.norm_kind)
        last = ctx.last_shard(x[:, -1])                    # (B, D)
        table = output_table(params_local, cfg)
        logits = (last @ table.T.astype(last.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits, {"scan": list(cache_stacks), "tail": tail_caches}

    bspec = {}
    if cfg.frontend == "encodec_stub":
        bspec["embeds"] = P(lay.bspec, lay.seq_axes, None)
    else:
        bspec["tokens"] = P(lay.bspec, lay.seq_axes)
        if cfg.arch_type == "vlm":
            bspec["embeds"] = P(lay.bspec, None, None)
    lspec = P(lay.bspec, "model" if vocab_sharded else None)
    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, bspec),
        out_specs=(lspec, cspecs),
        check_vma=False)

    sh = functools.partial(NamedSharding, mesh)
    jitted = jax.jit(
        body_sm,
        in_shardings=(jax.tree.map(sh, pspecs),
                      jax.tree.map(sh, bspec)),
        out_shardings=(sh(lspec), jax.tree.map(sh, cspecs)),
    )
    return jitted, lay, rules, lspec


# --------------------------------------------------------------------------
# chunked prefill
# --------------------------------------------------------------------------

def attn_chunk_prefill(p, spec: AttnSpec, cfg: ModelConfig, x, c, row_pos,
                       off, lay: ServeLayout, hp: ServeHParams,
                       page_map=None, state_map=None):
    """Attention sublayer over one prefill chunk.

    ``x`` (B,C,D) replicated over the sequence axes; ``row_pos`` (B,C)
    global positions of the chunk tokens (-1 = dead: idle row or past
    the row's remaining prompt); ``off`` (B,) the per-row chunk offset
    (-1 = row not prefilling this call).  Writes the chunk's K/V rows
    at their runtime offsets, attends *exactly* (prior columns via the
    flash-decode stats path, the chunk itself via a per-query causal
    pass, cross-shard stat combine), and in prism mode advances the
    Segment-Means capture over REAL columns only — the running
    per-segment sums ``zsum`` and counts ``gz`` ride in the cache, so
    a short prompt's kz/vz never average pad columns.

    Paged mode (``page_map`` (B, ppr) set): K/V writes scatter to each
    token's (page, offset) address and the prior columns gather through
    the row's leading pages; the Segment-Means running state lives in
    the state-page pool, read and written through ``state_map`` (B,)."""
    xn = norm(p["ln1"], x, cfg.norm_kind)
    q = attn_project_q(p["attn"], spec, xn, row_pos)
    k_new, v_new = attn_project_kv(p["attn"], spec, xn, row_pos)
    scale = spec.head_dim ** -0.5

    idx = _seq_index(lay.seq_axes)
    slot, owner, col_pos = _decode_cols(lay, idx, row_pos)
    # prior columns: everything before the chunk offset lives in the
    # leading [0, n_loc0) columns of the shard under BOTH placements
    # (aligned: by construction; rr: p < n0 => p//n_seq < n_loc0), so
    # the static slice / leading-page gather suffices and validity is
    # uniform over the chunk's queries
    n_loc0 = lay.n_loc0
    if page_map is not None:
        pc = c["k"].shape[1]
        colc = jnp.clip(slot, 0, lay.cap_l - 1)
        pg = jnp.take_along_axis(page_map, colc // pc, axis=1)
        flat = lambda a: a.reshape((-1,) + a.shape[2:])
        k_pool = _write_pool(c["k"], flat(k_new), flat(pg),
                             flat(colc % pc), flat(owner))
        v_pool = _write_pool(c["v"], flat(v_new), flat(pg),
                             flat(colc % pc), flat(owner))
        pages_pre = page_map[:, :n_loc0 // pc]
        k_pre = _gather_pages(k_pool, pages_pre)
        v_pre = _gather_pages(v_pool, pages_pre)
        mapped = jnp.repeat(pages_pre >= 0, pc, axis=1)
        valid = mapped & (col_pos[:n_loc0][None, :]
                          < jnp.maximum(off, 0)[:, None])
        new_c = dict(c, k=k_pool, v=v_pool)
    else:
        k_c = _write_chunk(c["k"], k_new, slot, owner)
        v_c = _write_chunk(c["v"], v_new, slot, owner)
        k_pre, v_pre = k_c[:, :n_loc0], v_c[:, :n_loc0]
        valid = col_pos[:n_loc0][None, :] < jnp.maximum(off, 0)[:, None]
        new_c = dict(c, k=k_c, v=v_c)

    # the chunk itself: causal over its own just-projected rows.  Each
    # chunk column contributes on the ONE shard that owns its cache
    # slot (a chunk may span a shard boundary) — the cross-shard psum
    # then sums disjoint column sets, keeping the combine exact.
    jj = jnp.arange(row_pos.shape[1])
    alive = row_pos >= 0
    bias_self = jnp.where(
        (jj[None, None, :] <= jj[None, :, None])
        & alive[:, :, None] & owner[:, None, :], 0.0, NEG_INF)
    out = chunk_attention(q, k_pre, v_pre, valid,
                          bias_self, k_new, v_new, lay.seq_axes, scale,
                          backend=hp.backend)

    if hp.decode_mode == "prism" and "kz" in c:
        lo, hi, mid, _, _ = _means_meta(lay)
        act = off >= 0                             # rows advanced this call
        seg = ((jnp.asarray(lo)[None, None, :] <= row_pos[:, :, None])
               & (row_pos[:, :, None] <= jnp.asarray(hi)[None, None, :]))
        if state_map is not None:
            sr = jnp.clip(state_map, 0, c["zsum"].shape[0] - 1)
            zs_prev = jnp.take(c["zsum"], sr, axis=0)
        else:
            zs_prev = c["zsum"]
        zsum = jnp.where((off == 0)[:, None, None], 0.0, zs_prev)
        zsum = zsum + jnp.einsum("bcm,bcd->bmd", seg.astype(jnp.float32),
                                 x.astype(jnp.float32))
        filled = jnp.maximum(off, 0) + alive.sum(axis=1)
        cnt = segment_fill_counts(lo, hi, filled)  # (B, m) real columns
        z = (zsum / jnp.maximum(cnt, 1.0)[..., None]).astype(x.dtype)
        kz, vz = attn_project_kv(p["attn"], spec,
                                 norm(p["ln1"], z, cfg.norm_kind),
                                 jnp.asarray(mid, jnp.float32))
        if state_map is not None:
            # state rows are unique per active slot, so the scatter has
            # no in-range duplicates; inactive rows route OOB (their
            # pool rows stay put — same as the dense where(act) select)
            S = c["zsum"].shape[0]
            dst = jnp.where(act & (state_map >= 0), state_map, S)
            new_c["kz"] = c["kz"].at[dst].set(
                kz.astype(c["kz"].dtype), mode="drop")
            new_c["vz"] = c["vz"].at[dst].set(
                vz.astype(c["vz"].dtype), mode="drop")
            new_c["gz"] = c["gz"].at[dst].set(cnt, mode="drop")
            new_c["zsum"] = c["zsum"].at[dst].set(zsum, mode="drop")
        else:
            sel = act[:, None, None, None]
            new_c["kz"] = jnp.where(sel, kz.astype(c["kz"].dtype), c["kz"])
            new_c["vz"] = jnp.where(sel, vz.astype(c["vz"].dtype), c["vz"])
            new_c["gz"] = jnp.where(act[:, None], cnt, c["gz"])
            new_c["zsum"] = zsum

    o = attn_output(p["attn"], out)
    if cfg.parallel_block:
        o = o + mlp(p["mlp"], xn, cfg.mlp_kind)
    return o, new_c


def block_chunk_prefill(cfg: ModelConfig, kind: str, p, shared, x, c,
                        row_pos, off, lay: ServeLayout, hp: ServeHParams,
                        page_map=None, state_map=None):
    """One residual block over a prefill chunk.  Returns (x, new_cache).
    Only position-addressed global-attention kinds are chunkable — the
    same set the serving engine admits."""
    if kind in ("attn", "moe"):
        spec = T.attn_spec(cfg, kind)
        o, c = attn_chunk_prefill(p, spec, cfg, x, c, row_pos, off,
                                  lay, hp, page_map, state_map)
        x = x + o
        if cfg.parallel_block:
            return x, c
        if kind == "moe":
            y, _ = moe_apply(p["moe"], norm(p["ln2"], x, cfg.norm_kind),
                             cfg, DecodeMoeCtx(tp=hp.decode_tp))
            x = x + y
        elif cfg.d_ff:
            x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind),
                        cfg.mlp_kind)
        return x, c
    if kind == "shared_attn":
        spec = T.attn_spec(cfg, "attn")
        o, c = attn_chunk_prefill(shared, spec, cfg, x, c, row_pos, off,
                                  lay, hp, page_map, state_map)
        x = x + o
        x = x + mlp(shared["mlp"], norm(shared["ln2"], x, cfg.norm_kind),
                    cfg.mlp_kind)
        return x, c
    raise ValueError(
        f"chunked prefill supports position-addressed attention caches "
        f"only (got block kind {kind!r})")


def make_chunk_prefill_step(cfg: ModelConfig, mesh, params, *,
                            batch: int, cap: int, prefill_len: int,
                            chunk_len: int,
                            hp: ServeHParams = ServeHParams(),
                            paging: PagedLayout | None = None):
    """jitted (params, cache, tokens (B,C), off (B,), nreal (B,)) -> cache
    (paged: two trailing (page_map (B,ppr), state_map (B,)) inputs).

    One compiled program advances every mid-prefill request by up to
    ``chunk_len`` prompt tokens: row ``i``'s tokens land at global
    positions ``[off[i], off[i] + nreal[i])`` of its cache row (idle
    rows pass ``off = -1``), interleaved by the engine's scheduler with
    single-token decode steps so long prompts never stall in-flight
    decodes.  The cache has the DECODE layout (``cap``/``prefill_len``
    as in ``make_serve_step``) — requests are admitted straight into
    their decode slot, with no grow/insert round trip; stale columns
    from a previous occupant are never visible because visibility
    (``col_pos < off`` / ``col_pos <= pos``) only ever reaches columns
    this request has already written.

    Exactness: chunk queries attend with the full cross-shard stat
    combine, so the written cache and any later decode are
    token-identical to the monolithic prefill (the equivalence tests
    pin this).  In prism decode mode the program additionally
    accumulates the Segment-Means state (kz/vz/gz/zsum) over real
    columns only.  Returns (jitted, layout, rules)."""
    lay = make_layout(cfg, mesh, batch, cap, hp, prefill_len,
                      _paged_placement(hp, paging))
    assert 1 <= chunk_len <= prefill_len, (chunk_len, prefill_len)
    rules = param_specs(params, mesh, cfg.vocab_size)
    pspecs = spec_tree(rules)
    cspecs = cache_specs(cfg, lay, hp, paging)
    vocab_sharded = (rules["embed"]["table"].kind == "vocab")
    shared_rules = rules.get("shared")
    u, n_units, _ = cfg.scan_split
    unit_kinds = cfg.block_kinds[:u]
    for kind in cfg.block_kinds:
        if kind not in ("attn", "moe", "shared_attn"):
            raise ValueError(
                f"chunked prefill needs position-addressed attention "
                f"caches; arch {cfg.name!r} has block kind {kind!r}")

    def body_core(params_local, cache_local, tokens, off, nreal,
                  page_map, state_map):
        trace_counts["chunk_prefill_step"] += 1
        j = jnp.arange(chunk_len)
        alive = (off[:, None] >= 0) & (j[None, :] < nreal[:, None])
        row_pos = jnp.where(alive, off[:, None] + j[None, :], -1)
        x = embed_tokens(cfg, params_local, rules, tokens, row_pos,
                         sharded_vocab=vocab_sharded)

        def unit_body(x, xs):
            p_sl, c_sl = xs
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            new = []
            for k, kind in enumerate(unit_kinds):
                p = gather_tree(p_sl[k], rules["scan"][k])
                x, nc = block_chunk_prefill(cfg, kind, p, shared, x,
                                            c_sl[k], row_pos, off, lay, hp,
                                            page_map, state_map)
                new.append(nc)
            return x, tuple(new)

        x, new_stacks = lax.scan(
            unit_body, x,
            (tuple(params_local["scan"]), tuple(cache_local["scan"])))

        new_tail = []
        for t, tree in enumerate(params_local["tail"]):
            kind = cfg.block_kinds[n_units * u + t]
            p = gather_tree(tree, rules["tail"][t])
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            x, nc = block_chunk_prefill(cfg, kind, p, shared, x,
                                        cache_local["tail"][t], row_pos,
                                        off, lay, hp, page_map, state_map)
            new_tail.append(nc)
        # no logits: the engine's rewind re-feeds the last prompt token
        # as the first decode step (idempotent K/V rewrite), which is
        # what produces the teacher-forced next-token logits
        return {"scan": list(new_stacks), "tail": new_tail}

    if paging is not None:
        body = body_core
        in_specs = (pspecs, cspecs, P(None), P(None), P(None),
                    P(None), P(None))
    else:
        def body(params_local, cache_local, tokens, off, nreal):
            return body_core(params_local, cache_local, tokens, off,
                             nreal, None, None)
        in_specs = (pspecs, cspecs, P(lay.bspec, None), P(lay.bspec),
                    P(lay.bspec))
    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=cspecs,
        check_vma=False)

    sh = functools.partial(NamedSharding, mesh)
    jitted = jax.jit(
        body_sm,
        in_shardings=tuple(jax.tree.map(sh, s) for s in in_specs),
        out_shardings=jax.tree.map(sh, cspecs),
        donate_argnums=(1,),
    )
    return jitted, lay, rules


# --------------------------------------------------------------------------
# token-packed unified serving step (mixed prefill + decode per tick)
# --------------------------------------------------------------------------

def packed_attention(q, k, v, valid, bias_self, k_new, v_new, axes, scale,
                     backend="auto"):
    """Exact attention for one token-packed tick — the ragged
    multi-request generalization of ``chunk_attention``.  Two disjoint
    column sets, two passes:

      * **prior columns** — each packed token attends its own request's
        already-written cache row (gathered per token), with validity
        stopping strictly before the request's tick-start offset, so
        the single-token flash-decode stats path applies verbatim with
        T tokens as the batch axis (no query folding needed: Nq = 1
        per token);
      * **intra-tick columns** — the T just-projected K/V rows under a
        segment-id-masked causal bias (``bias_self (1,T,T)``): tokens
        of different requests NEVER attend to each other, and each
        column contributes on the one shard pair owning its cache
        address.

    The stat triples merge associatively and the cross-shard combine
    runs over the sequence AND batch axes (``axes``) — shards that do
    not hold a token's cache row contribute empty stats and cancel —
    so packed output is exact and replicated on every device.

    q (T,1,Hq,hd); k,v (T,M,Hkv,hd) per-token gathered cache rows;
    valid (T,M); k_new,v_new (T,1,Hkv,hd).  Returns (T,1,Hq,hd)."""
    if use_pallas(backend):
        m1, l1, a1 = flash_decode_stats(q, k, v, valid, scale=scale,
                                        interpret=pallas_interpret())
    else:
        m1, l1, a1 = decode_stats_reference(q, k, v, valid, scale=scale)
    # (T,Hq,1,1)/(T,1,Hq,hd) -> the Nq = T shapes merge_stats expects
    stats_prior = (m1[:, :, 0, 0].T[None, :, :, None],
                   l1[:, :, 0, 0].T[None, :, :, None],
                   a1[:, 0][None])
    stats_self = chunk_softmax_stats(q[:, 0][None], k_new[:, 0][None],
                                     v_new[:, 0][None], bias_self, scale)
    m_p, l_p, acc_p = merge_stats(stats_prior, stats_self)
    out = _combine_exact(m_p, l_p, acc_p, axes)           # (1,T,Hq,hd)
    return out[0][:, None].astype(v.dtype)


def attn_packed(p, spec: AttnSpec, cfg: ModelConfig, x, c, meta,
                lay: ServeLayout, hp: ServeHParams,
                page_map=None, state_map=None):
    """Attention sublayer over one token-packed tick.

    ``x`` (T,1,D) replicated; ``meta = (slot, pos, off, is_prefill,
    row_loc, owned)`` — the per-token packing metadata (slot = -1 dead
    entry) plus this batch shard's local cache row per token and
    whether it owns it.  Writes every real token's K/V at its runtime
    (slot, position) address, attends exactly (prior columns via
    per-token flash-decode stats over the token's gathered cache row,
    intra-tick columns via the segment-masked causal pass, stat combine
    over sequence and batch axes), serves decode tokens the prism
    owner-view over the means cache when configured, and in prism mode
    advances the per-request Segment-Means running state over the REAL
    prefill tokens only — the flat-token twin of the chunk path's
    accumulation, so a prompt that arrives packed produces bit-equal
    gz/zsum (and kz/vz) to one that arrives chunked.

    Paged mode (``page_map`` (n_slots, ppr) set): every replica holds
    the full pool and packs every token (``row_loc = slot``), per-token
    K/V writes scatter to (page, offset) addresses, the token's virtual
    cache row gathers through its slot's page list, and the stat
    combine runs over the sequence axes ONLY (batch replicas are
    identical — a psum over them would over-count the prism
    owner-select).  Prism state reads/writes go through ``state_map``
    (n_slots,) into the state-page pool."""
    slot, pos, off, is_prefill, row_loc, owned = meta
    xn = norm(p["ln1"], x, cfg.norm_kind)
    rp = pos[:, None]                          # (T,1) token positions
    q = attn_project_q(p["attn"], spec, xn, rp)
    k_new, v_new = attn_project_kv(p["attn"], spec, xn, rp)
    scale = spec.head_dim ** -0.5
    axes_all = (tuple(lay.seq_axes) if page_map is not None
                else tuple(lay.seq_axes) + tuple(lay.ba))

    idx = _seq_index(lay.seq_axes)
    col, seq_owner, col_pos = _decode_cols(lay, idx, pos)
    alive = pos >= 0
    wr = seq_owner & owned & alive
    if page_map is not None:
        pc = c["k"].shape[1]
        b_loc = page_map.shape[0]
        row = jnp.clip(row_loc, 0, b_loc - 1)
        colc = jnp.clip(col, 0, lay.cap_l - 1)
        pages_t = jnp.take(page_map, row, axis=0)          # (T, ppr)
        pg = jnp.take_along_axis(pages_t, (colc // pc)[:, None],
                                 axis=1)[:, 0]
        k_pool = _write_pool(c["k"], k_new[:, 0], pg, colc % pc, wr)
        v_pool = _write_pool(c["v"], v_new[:, 0], pg, colc % pc, wr)
        new_c = dict(c, k=k_pool, v=v_pool)
        # one gather per SLOT, then a per-token row take — same shape
        # the dense path produces from its row cache
        k_t = jnp.take(_gather_pages(k_pool, page_map), row, axis=0)
        v_t = jnp.take(_gather_pages(v_pool, page_map), row, axis=0)
        mapped = jnp.take(jnp.repeat(page_map >= 0, pc, axis=1),
                          row, axis=0)                     # (T, cap_l)
    else:
        k_c = _write_packed(c["k"], k_new[:, 0], row_loc, col, wr)
        v_c = _write_packed(c["v"], v_new[:, 0], row_loc, col, wr)
        new_c = dict(c, k=k_c, v=v_c)
        b_loc = k_c.shape[0]
        row = jnp.clip(row_loc, 0, b_loc - 1)
        k_t = jnp.take(k_c, row, axis=0)       # (T, cap_l, Hkv, hd)
        v_t = jnp.take(v_c, row, axis=0)
        mapped = True

    # prior columns: strictly before the request's tick-start offset,
    # on the batch shard holding the slot (others: empty stats)
    valid = (mapped & (owned & alive)[:, None]
             & (col_pos[None, :] < jnp.maximum(off, 0)[:, None]))
    # intra-tick columns: same request only — tokens of different
    # requests must never attend to each other — causal, each column
    # on the one (batch, sequence) shard pair owning its address
    ok_q = (slot >= 0) & alive
    ok_k = ok_q & seq_owner & owned
    bias_self = jnp.where(
        (slot[None, :, None] == slot[None, None, :])
        & (pos[None, None, :] <= pos[None, :, None])
        & ok_q[None, :, None] & ok_k[None, None, :], 0.0, NEG_INF)
    out = packed_attention(q, k_t, v_t, valid, bias_self, k_new, v_new,
                           axes_all, scale, backend=hp.backend)

    if hp.decode_mode == "prism" and "kz" in c:
        # decode tokens take the paper's owner view over the means
        # cache (identical semantics to attn_decode) with every
        # per-request input gathered per token; prefill tokens keep
        # the exact combine above, as on the chunked path
        lo, hi, mid, _, shard_of = _means_meta(lay)
        if state_map is not None:
            st = jnp.clip(jnp.take(state_map, row),
                          0, c["gz"].shape[0] - 1)         # (T,)
            cnt_t = jnp.take(c["gz"], st, axis=0)          # (T, m)
            kz_t = jnp.take(c["kz"], st, axis=0)
            vz_t = jnp.take(c["vz"], st, axis=0)
        else:
            cnt_t = jnp.take(c["gz"], row, axis=0)         # (T, m)
            kz_t = jnp.take(c["kz"], row, axis=0)
            vz_t = jnp.take(c["vz"], row, axis=0)
        gz = jnp.where(
            (jnp.asarray(shard_of)[None, :] != idx)
            & (jnp.asarray(lo)[None, :] + cnt_t <= pos[:, None] + 1)
            & (owned & alive)[:, None],
            cnt_t, 0.0)
        valid_le = (mapped & (owned & alive)[:, None]
                    & (col_pos[None, :] <= pos[:, None]))
        sel = seq_owner & owned & alive & (is_prefill == 0)
        out_pz = decode_attention(q, k_t, v_t, valid_le, axes_all,
                                  scale, gz=gz, kz=kz_t, vz=vz_t,
                                  owner=sel, mode="prism",
                                  backend=hp.backend)
        out = jnp.where((is_prefill != 0)[:, None, None, None],
                        out, out_pz)

        # Segment-Means capture over the tick's REAL prefill tokens
        upd = (is_prefill != 0) & owned & alive
        r_upd = jnp.where(upd, row_loc, b_loc)             # OOB -> drop
        big = jnp.int32(1 << 30)
        off_b = jnp.full((b_loc,), big, jnp.int32).at[r_upd].min(
            jnp.where(upd, off, big), mode="drop")
        filled = jnp.zeros((b_loc,), jnp.int32).at[r_upd].max(
            jnp.where(upd, pos + 1, 0), mode="drop")
        act = jnp.zeros((b_loc,), jnp.int32).at[r_upd].max(
            upd.astype(jnp.int32), mode="drop") > 0
        onehot = r_upd[:, None] == jnp.arange(b_loc)[None, :]
        seg = ((jnp.asarray(lo)[None, :] <= pos[:, None])
               & (pos[:, None] <= jnp.asarray(hi)[None, :]))
        if state_map is not None:
            S = c["zsum"].shape[0]
            sr = jnp.clip(state_map, 0, S - 1)
            zs_prev = jnp.take(c["zsum"], sr, axis=0)      # (n_slots, ...)
        else:
            zs_prev = c["zsum"]
        zsum = jnp.where((act & (off_b == 0))[:, None, None], 0.0,
                         zs_prev)
        zsum = zsum + jnp.einsum("tb,tm,td->bmd",
                                 onehot.astype(jnp.float32),
                                 seg.astype(jnp.float32),
                                 x[:, 0].astype(jnp.float32))
        cnt = segment_fill_counts(lo, hi, filled)          # (b_loc, m)
        z = (zsum / jnp.maximum(cnt, 1.0)[..., None]).astype(x.dtype)
        kz, vz = attn_project_kv(p["attn"], spec,
                                 norm(p["ln1"], z, cfg.norm_kind),
                                 jnp.asarray(mid, jnp.float32))
        if state_map is not None:
            # unique state row per active slot; inactive slots route
            # OOB and keep their pool rows (the dense where(act) select)
            dst = jnp.where(act & (state_map >= 0), state_map, S)
            new_c["kz"] = c["kz"].at[dst].set(
                kz.astype(c["kz"].dtype), mode="drop")
            new_c["vz"] = c["vz"].at[dst].set(
                vz.astype(c["vz"].dtype), mode="drop")
            new_c["gz"] = c["gz"].at[dst].set(cnt, mode="drop")
            new_c["zsum"] = c["zsum"].at[dst].set(zsum, mode="drop")
        else:
            sel_b = act[:, None, None, None]
            new_c["kz"] = jnp.where(sel_b, kz.astype(c["kz"].dtype),
                                    c["kz"])
            new_c["vz"] = jnp.where(sel_b, vz.astype(c["vz"].dtype),
                                    c["vz"])
            new_c["gz"] = jnp.where(act[:, None], cnt, c["gz"])
            new_c["zsum"] = zsum

    o = attn_output(p["attn"], out)
    if cfg.parallel_block:
        o = o + mlp(p["mlp"], xn, cfg.mlp_kind)
    return o, new_c


def block_packed(cfg: ModelConfig, kind: str, p, shared, x, c, meta,
                 lay: ServeLayout, hp: ServeHParams,
                 page_map=None, state_map=None):
    """One residual block over a token-packed tick.  Returns
    (x, new_cache).  Same chunkable-kind restriction as the engine."""
    if kind in ("attn", "moe"):
        spec = T.attn_spec(cfg, kind)
        o, c = attn_packed(p, spec, cfg, x, c, meta, lay, hp,
                           page_map, state_map)
        x = x + o
        if cfg.parallel_block:
            return x, c
        if kind == "moe":
            y, _ = moe_apply(p["moe"], norm(p["ln2"], x, cfg.norm_kind),
                             cfg, DecodeMoeCtx(tp=hp.decode_tp))
            x = x + y
        elif cfg.d_ff:
            x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind),
                        cfg.mlp_kind)
        return x, c
    if kind == "shared_attn":
        spec = T.attn_spec(cfg, "attn")
        o, c = attn_packed(shared, spec, cfg, x, c, meta, lay, hp,
                           page_map, state_map)
        x = x + o
        x = x + mlp(shared["mlp"], norm(shared["ln2"], x, cfg.norm_kind),
                    cfg.mlp_kind)
        return x, c
    raise ValueError(
        f"packed serving supports position-addressed attention caches "
        f"only (got block kind {kind!r})")


def make_packed_step(cfg: ModelConfig, mesh, params, *,
                     batch: int, cap: int, prefill_len: int,
                     token_budget: int,
                     hp: ServeHParams = ServeHParams(),
                     paging: PagedLayout | None = None):
    """jitted (params, cache, tokens (T,), slot (T,), pos (T,),
    off (T,), is_prefill (T,)) -> (logits (min(batch,T), V), cache)
    (paged: two trailing (page_map (B,ppr), state_map (B,)) inputs) —
    ONE compiled program per engine tick over a flat token-packed
    batch of ``T = token_budget`` mixed prefill + decode tokens.

    Each entry of the (T,) metadata vectors describes one packed
    token: ``slot`` the decode slot (cache batch row) it belongs to
    (-1 = dead entry; ragged budgets leave the tail dead), ``pos`` its
    global position, ``off`` the first position its request packs this
    tick (a decode token has off == pos — its prior columns are
    everything strictly before it, its own column rides the intra-tick
    pass), ``is_prefill`` 1 for prompt tokens (never sampled; the
    engine keeps the rewind) and 0 for decode tokens.  The LM head
    runs over the static decode prefix only — ``plan_tick`` packs
    decode tokens first, so ``logits`` is ``(min(batch, T), V)``.
    Per-tick cost scales with the REAL packed tokens, not
    ``n_slots × chunk_len`` — the fleet-level token packing the
    chunked engine's FLOP clock called for.

    The cache has the DECODE layout and is written in place with
    owner-masked scatters (no grow/insert round trip); in prism decode
    mode the program additionally advances the per-request
    Segment-Means state (kz/vz/gz/zsum) over the tick's real prompt
    tokens only.  Output is token-for-token identical to chunked and
    sequential serving in both decode modes (the packed equivalence
    tests pin this on the 2x4 mesh).  Returns
    (jitted, layout, rules, logits_spec)."""
    lay = make_layout(cfg, mesh, batch, cap, hp, prefill_len,
                      _paged_placement(hp, paging))
    assert token_budget >= 1, token_budget
    assert not hp.decode_tp, "packed serving does not support decode_tp"
    rules = param_specs(params, mesh, cfg.vocab_size)
    pspecs = spec_tree(rules)
    cspecs = cache_specs(cfg, lay, hp, paging)
    vocab_sharded = (rules["embed"]["table"].kind == "vocab")
    shared_rules = rules.get("shared")
    u, n_units, _ = cfg.scan_split
    unit_kinds = cfg.block_kinds[:u]
    for kind in cfg.block_kinds:
        if kind not in ("attn", "moe", "shared_attn"):
            raise ValueError(
                f"packed serving needs position-addressed attention "
                f"caches; arch {cfg.name!r} has block kind {kind!r}")
    axes = mesh_axes(mesh)
    n_b = int(np.prod([axes[a] for a in lay.ba])) if lay.ba else 1
    b_loc = batch // n_b
    head_rows = min(batch, token_budget)   # decode tokens pack first

    def body_core(params_local, cache_local, tokens, slot, pos, off,
                  pre, page_map, state_map):
        trace_counts["packed_step"] += 1
        if paging is not None:
            # pool replicated over the batch axes: every replica packs
            # every token against the full page pool
            row_loc = slot
            owned = slot >= 0
        else:
            didx = _batch_index(lay.ba)
            row_loc = jnp.where(slot >= 0, slot - didx * b_loc, -1)
            owned = (row_loc >= 0) & (row_loc < b_loc)
        meta = (slot, pos, off, pre, row_loc, owned)
        x = embed_token(cfg, params_local, rules, tokens, pos,
                        sharded_vocab=vocab_sharded)

        def unit_body(x, xs):
            p_sl, c_sl = xs
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            new = []
            for j, kind in enumerate(unit_kinds):
                p = gather_tree(p_sl[j], rules["scan"][j])
                x, nc = block_packed(cfg, kind, p, shared, x, c_sl[j],
                                     meta, lay, hp, page_map, state_map)
                new.append(nc)
            return x, tuple(new)

        x, new_stacks = lax.scan(
            unit_body, x,
            (tuple(params_local["scan"]), tuple(cache_local["scan"])))

        new_tail = []
        for t, tree in enumerate(params_local["tail"]):
            kind = cfg.block_kinds[n_units * u + t]
            p = gather_tree(tree, rules["tail"][t])
            shared = (gather_tree(params_local["shared"], shared_rules)
                      if shared_rules else None)
            x, nc = block_packed(cfg, kind, p, shared, x,
                                 cache_local["tail"][t], meta, lay, hp,
                                 page_map, state_map)
            new_tail.append(nc)

        x = norm(params_local["final_norm"], x, cfg.norm_kind)
        table = output_table(params_local, cfg)
        # only decode tokens are ever sampled, and plan_tick packs them
        # first — at most n_slots of them — so the LM head runs over
        # the static decode prefix, not the whole budget (a prefill-
        # heavy tick would otherwise pay budget/n_slots times the
        # needed head FLOPs on logits nobody reads)
        xh = x[:head_rows, 0]
        logits = (xh @ table.T.astype(xh.dtype)).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits, {"scan": list(new_stacks), "tail": new_tail}

    vspec = P(None)                    # packed vectors ride replicated
    lspec = P(None, "model" if vocab_sharded else None)
    if paging is not None:
        body = body_core
        in_specs = (pspecs, cspecs, vspec, vspec, vspec, vspec, vspec,
                    P(None), P(None))
    else:
        def body(params_local, cache_local, tokens, slot, pos, off, pre):
            return body_core(params_local, cache_local, tokens, slot,
                             pos, off, pre, None, None)
        in_specs = (pspecs, cspecs, vspec, vspec, vspec, vspec, vspec)
    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(lspec, cspecs),
        check_vma=False)

    sh = functools.partial(NamedSharding, mesh)
    jitted = jax.jit(
        body_sm,
        in_shardings=tuple(jax.tree.map(sh, s) for s in in_specs),
        out_shardings=(sh(lspec), jax.tree.map(sh, cspecs)),
        donate_argnums=(1,),
    )
    return jitted, lay, rules, lspec


def make_result_pack(n_slots: int):
    """Device-side result packing for the streaming engine
    (JetStream's ``ResultTokens`` idiom): build the ONE small device
    array a tick sends home, plus the merge that feeds the previous
    in-flight tick's on-device samples into the next tick's token
    batch.  Returns ``(pack, merge)``:

    * ``pack(logits (L, V), row_slot (L,) i32, is_decode (L,) i32,
      lengths (n_slots,) i32) -> data (n_slots, 4) i32`` — per-slot
      rows ``[token, valid, length, finite]``.  ``token`` is the
      greedy argmax of the slot's decode logits row this tick
      (first-max tie break — bit-identical to the host ``np.argmax``
      the synchronous engine samples with), ``valid`` is 1 iff the
      slot decoded this tick, ``length`` is the host-supplied cache
      length after the row, and ``finite`` is 0 iff the row held a
      non-finite logit (the NaN-quarantine trigger, reduced on device
      so the host copy stays one small array instead of (B, V)
      logits).  Slots without a decode row come back ``[0, 0, length,
      1]``.
    * ``merge(tok_host (T,) i32, src (T,) i32, prev (n_slots, 4) i32)
      -> (T,) i32`` — the double-buffer splice: entry ``i`` takes
      ``prev[src[i], 0]`` (the previous tick's sampled token for that
      slot, still device-resident) when ``src[i] >= 0``, else the
      host-planned ``tok_host[i]`` (prefill tokens, rewind re-feeds,
      and the first decode row after a reconciled tick).

    Both are ``jax.jit`` closures over plain ``jnp`` — logits arrive
    with whatever sharding the step program produced and GSPMD places
    the argmax/reduction accordingly.  Non-decode rows scatter to the
    out-of-bounds index ``n_slots`` and are dropped (``mode='drop'``,
    the same contract the paged pool's scatter writes rely on), so a
    ragged tick never corrupts a neighbouring slot's entry.
    """
    S = n_slots

    @jax.jit
    def pack(logits, row_slot, is_decode, lengths):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        fin = jnp.isfinite(logits).all(axis=-1)
        idx = jnp.where(is_decode > 0, row_slot, S)
        zeros = jnp.zeros((S,), jnp.int32)
        tok_s = zeros.at[idx].set(tok, mode="drop")
        val_s = zeros.at[idx].set(1, mode="drop")
        fin_s = jnp.ones((S,), jnp.int32).at[idx].set(
            fin.astype(jnp.int32), mode="drop")
        return jnp.stack(
            [tok_s, val_s, lengths.astype(jnp.int32), fin_s], axis=1)

    @jax.jit
    def merge(tok_host, src, prev):
        pick = prev[:, 0][jnp.clip(src, 0, S - 1)]
        return jnp.where(src >= 0, pick, tok_host)

    return pack, merge

"""Losses.  The LM loss is *vocab-chunked*: for 256k-vocab architectures the
full (B, N, V) logits tensor would dominate HBM (command-r train_4k:
16×256×256000×4B ≈ 4 GB/device just for logits), so we scan over sequence
chunks and never materialize more than (B, chunk, V)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels):
    """(..., V) vs int labels (...,) -> mean nll."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def chunked_lm_loss(features, embed_table, labels, *, chunk: int = 256,
                    softcap=None):
    """features (B,N,D) @ tableᵀ (V,D) -> mean xent, scanning N in chunks."""
    b, n, d = features.shape
    chunk = min(chunk, n)
    assert n % chunk == 0
    nc = n // chunk
    f = features.reshape(b, nc, chunk, d).swapaxes(0, 1)   # (nc,B,c,D)
    y = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        fc, yc = xs
        logits = fc @ embed_table.T.astype(fc.dtype)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), yc[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (f, y))
    return total / (b * n)

"""Sharded training step: PRISM sequence-parallel forward, FSDP parameter
gathering, vocab-parallel loss, explicit gradient reductions, AdamW.

Structure (DESIGN.md §4):
  outer jax.jit
    ├─ shard_map body: per-device forward + backward with explicit
    │    collectives (PRISM Segment-Means all-gather per block, FSDP
    │    param all-gather per layer, vocab-parallel chunked loss,
    │    gradient psums per sharding rule)
    └─ global-norm clip + AdamW in auto-SPMD land (optimizer state can
         carry different sharding; XLA inserts the reshards)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import axis_size, shard_map
from ..core.protocol import PrismConfig
from ..models import transformer as T
from ..models.config import ModelConfig
from ..models.layers import norm
from ..optim import adamw_update, clip_by_global_norm, cosine_schedule
from ..sharding.context import ShardedPrismContext
from ..sharding.rules import (GradReduce, gather_tree, opt_state_specs,
                              param_specs, spec_tree)
from ..launch.mesh import batch_axes, mesh_axes


# --------------------------------------------------------------------------
# vocab-parallel embedding + loss (embed table sharded over 'model' on vocab)
# --------------------------------------------------------------------------

def embed_vp(table_local, tokens, *, sharded_vocab: bool):
    """Vocab-parallel lookup.  tokens are SEQ-sharded and the table is
    VOCAB-sharded over the same 'model' axis, so each device first gathers
    all token ids (cheap ints), contributes its vocab shard's rows for the
    *full* sequence, and a psum_scatter sums the partials while handing
    each device back exactly its own sequence shard."""
    if not sharded_vocab:
        return jnp.take(table_local, tokens, axis=0)
    v_loc = table_local.shape[0]
    vstart = lax.axis_index("model") * v_loc
    tg = lax.all_gather(tokens, "model", axis=1, tiled=True)   # (B, N)
    t = tg - vstart
    valid = (t >= 0) & (t < v_loc)
    emb = jnp.take(table_local, jnp.clip(t, 0, v_loc - 1), axis=0)
    emb = jnp.where(valid[..., None], emb, 0)
    return lax.psum_scatter(emb, "model", scatter_dimension=1, tiled=True)


def vp_lm_loss(x_local, table_local, labels_local, *, softcap=None,
               sharded_vocab: bool, n_chunks: int = 16,
               global_tokens: int = 0):
    """x_local (B, N_loc, D) seq-sharded over 'model'; table_local
    (V_loc, D) vocab-sharded over 'model'.  Gathers activations chunk by
    chunk (Megatron-style sequence-parallel → vocab-parallel transition;
    the gather's transpose reduce-scatters the backward).

    Returns the *local-share* mean: token-nll summed over whatever tokens
    this device computed, divided by the GLOBAL token count — so a plain
    psum over the relevant axes reconstructs the global mean, and gradient
    contributions combine without double counting."""
    b, n_loc, d = x_local.shape
    v_loc = table_local.shape[0]
    n_chunks = min(n_chunks, n_loc)
    while n_loc % n_chunks:
        n_chunks -= 1
    xc = x_local.reshape(b, n_chunks, n_loc // n_chunks, d).swapaxes(0, 1)
    yc = labels_local.reshape(b, n_chunks, -1).swapaxes(0, 1)
    vstart = (lax.axis_index("model") * v_loc) if sharded_vocab else 0

    def body(carry, xs):
        x_c, y_c = xs
        if sharded_vocab:
            x_c = lax.all_gather(x_c, "model", axis=1, tiled=True)
            y_c = lax.all_gather(y_c, "model", axis=1, tiled=True)
        logits = (x_c @ table_local.T.astype(x_c.dtype)).astype(jnp.float32)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        mx = lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
        if sharded_vocab:
            mx = lax.pmax(mx, "model")
        ssum = jnp.sum(jnp.exp(logits - mx), -1)
        if sharded_vocab:
            ssum = lax.psum(ssum, "model")
        lse = mx[..., 0] + jnp.log(ssum)
        t = y_c - vstart
        valid = (t >= 0) & (t < v_loc)
        gold = jnp.take_along_axis(
            logits, jnp.clip(t, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(valid, gold, 0.0)
        if sharded_vocab:
            gold = lax.psum(gold, "model")
        return carry + (lse - gold).sum(), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    if sharded_vocab:
        # `total` is the all-model-shards sum (post-psum, replicated over
        # 'model'); convert to this device's share so downstream psums
        # remain uniform across both vocab modes.
        total = total / axis_size("model")
    return total / global_tokens


# --------------------------------------------------------------------------
# sharded forward (per-layer FSDP gather + PRISM context)
# --------------------------------------------------------------------------

def output_table(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"]
    w = params["lm_head"]["w"]          # (D, V_loc) -> (V_loc, D)
    return w.T


def sharded_forward(cfg: ModelConfig, params, rules, batch, ctx,
                    *, remat: bool = True, chunk: int = 128):
    """Returns (features (B, N_loc, D), aux)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    n_loc = (tokens.shape[1] if tokens is not None else embeds.shape[1])
    start = ctx._index() * n_loc

    vocab_sharded = (rules["embed"]["table"].kind == "vocab"
                     if "embed" in rules else False)
    if tokens is not None:
        x = embed_vp(params["embed"]["table"], tokens,
                     sharded_vocab=vocab_sharded)
    else:
        fp = gather_tree(params["frontend_proj"], rules["frontend_proj"])
        x = embeds @ fp["w"].astype(embeds.dtype)
    if cfg.arch_type == "vlm" and embeds is not None and tokens is not None:
        # image prefix injection: embeds (B, prefix, D) replicated
        pe = gather_tree(params["frontend_proj"], rules["frontend_proj"])
        proj = pe["w"].astype(embeds.dtype)
        fe = embeds @ proj
        pos = start + jnp.arange(n_loc)
        idx = jnp.clip(pos, 0, cfg.prefix_len - 1)
        fe_rows = jnp.take(fe, idx, axis=1)
        x = jnp.where((pos < cfg.prefix_len)[None, :, None], fe_rows, x)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos == "learned":
        tbl = gather_tree(params["pos_embed"], rules["pos_embed"])["table"]
        x = x + lax.dynamic_slice_in_dim(tbl, start, n_loc).astype(x.dtype)
    elif cfg.pos == "sincos":
        x = x + T.sincos_embed(n_loc, cfg.d_model, start).astype(x.dtype)

    shared_rules = rules.get("shared")
    aux_total = jnp.zeros((), jnp.float32)
    u, n_units, n_tail = cfg.scan_split
    unit_kinds = cfg.block_kinds[:u]

    def unit_body(x, sliced, shared_local):
        """One repeating unit (u sublayers) — the lax.scan body."""
        shared = (gather_tree(shared_local, shared_rules)
                  if shared_rules else None)
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(unit_kinds):
            p = gather_tree(sliced[j], rules["scan"][j])
            x, a = T.block_apply(cfg, kind, p, shared, x, ctx, chunk=chunk)
            aux = aux + a
        return x, aux

    fn = jax.checkpoint(unit_body) if remat else unit_body
    if n_units > 1:
        x, auxs = lax.scan(
            lambda c, xs: fn(c, xs, params.get("shared")),
            x, tuple(params["scan"]))
        aux_total = aux_total + auxs.sum()
    else:
        x, aux = fn(x, tuple(T.layer_slice(s, 0)
                             if jax.tree.leaves(s) else s
                             for s in params["scan"]),
                    params.get("shared"))
        aux_total = aux_total + aux

    for t, tree in enumerate(params["tail"]):
        kind = cfg.block_kinds[n_units * u + t]

        def one_block(x, p_local, shared_local, _kind=kind, _t=t):
            p = gather_tree(p_local, rules["tail"][_t])
            shared = (gather_tree(shared_local, shared_rules)
                      if shared_rules else None)
            return T.block_apply(cfg, _kind, p, shared, x, ctx, chunk=chunk)

        tfn = jax.checkpoint(one_block) if remat else one_block
        x, aux = tfn(x, tree, params.get("shared"))
        aux_total = aux_total + aux

    x = norm(params["final_norm"], x, cfg.norm_kind)
    return x, aux_total


# --------------------------------------------------------------------------
# train step factory
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10000
    grad_clip: float = 1.0
    weight_decay: float = 0.1
    loss_chunks: int = 16
    remat: bool = True
    ssm_chunk: int = 128


def batch_spec(cfg: ModelConfig, mesh):
    ba = batch_axes(mesh)
    spec = {"tokens": P(ba, "model"), "labels": P(ba, "model")}
    if cfg.arch_type == "vlm":
        spec["embeds"] = P(ba, None, None)       # replicated prefix
    elif cfg.frontend == "encodec_stub":
        spec["embeds"] = P(ba, "model", None)
        del spec["tokens"]
    return spec


def make_train_step(cfg: ModelConfig, mesh, params, prism: PrismConfig,
                    hp: TrainHParams = TrainHParams()):
    rules = param_specs(params, mesh, cfg.vocab_size)
    pspecs = spec_tree(rules)
    ospecs = opt_state_specs(rules, params, mesh)
    bspec = batch_spec(cfg, mesh)
    axes = mesh_axes(mesh)
    n_model = axes["model"]
    n_devices = int(np.prod(list(axes.values())))
    ba = batch_axes(mesh)
    all_ax = tuple(mesh.axis_names)
    vocab_sharded = (rules["embed"]["table"].kind == "vocab"
                     if cfg.tie_embeddings else
                     rules["lm_head"]["w"].kind == "vocab")

    def body(params_local, batch_local):
        ctx = ShardedPrismContext(prism, n_shards=n_model,
                                  prefix_len=cfg.prefix_len)
        some = next(iter(batch_local.values()))
        b_loc = some.shape[0]
        n_loc = (batch_local["labels"].shape[1])
        global_tokens = b_loc * n_loc * n_devices

        def loss_fn(pl):
            feats, aux = sharded_forward(
                cfg, pl, rules, batch_local, ctx,
                remat=hp.remat, chunk=hp.ssm_chunk)
            table = output_table(pl, cfg)
            nll = vp_lm_loss(feats, table, batch_local["labels"],
                             softcap=cfg.logit_softcap,
                             sharded_vocab=vocab_sharded,
                             n_chunks=hp.loss_chunks,
                             global_tokens=global_tokens)
            # aux is a per-device statistic; average it over the mesh so
            # the psum-combined gradient matches the mean-aux objective.
            return nll + cfg.router_aux_weight * aux / n_devices, (nll, aux)

        (loss, (nll, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params_local)
        grads = GradReduce.apply(grads, rules, mesh)
        metrics = {
            "loss": lax.psum(nll, all_ax),
            "moe_aux": lax.pmean(aux, all_ax),
        }
        return grads, metrics

    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, bspec),
        out_specs=(pspecs, P()),
        check_vma=False)

    def step(params, opt_state, batch):
        grads, metrics = body_sm(params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.grad_clip)
        lr = cosine_schedule(opt_state["step"], base_lr=hp.lr,
                             warmup=hp.warmup, total=hp.total_steps)
        new_params, new_opt = adamw_update(
            params, grads, opt_state, lr=lr,
            weight_decay=hp.weight_decay)
        metrics = dict(metrics, gnorm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_sh = {"m": jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs),
              "v": jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs),
              "step": NamedSharding(mesh, P())}
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)
    rep = NamedSharding(mesh, P())

    jitted = jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh,
                       {"loss": rep, "moe_aux": rep, "gnorm": rep, "lr": rep}),
        donate_argnums=(0, 1),
    )
    return jitted, rules, param_sh, opt_sh, batch_sh

"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # block pattern: one kind per layer; kinds:
    #   attn, attn_local, moe, mlstm, slstm, mamba, shared_attn
    blocks: tuple = ()             # () => ('attn',) * n_layers
    mlp_kind: str = "swiglu"
    norm_kind: str = "rmsnorm"
    pos: str = "rope"              # rope | learned | none
    rope_theta: float = 10000.0
    rope_theta_local: float = 10000.0   # gemma3 dual-theta
    causal: bool = True
    attn_bias: bool = False
    qk_norm: bool = False
    logit_softcap: Optional[float] = None
    parallel_block: bool = False   # command-r: attn & mlp from one norm
    tie_embeddings: bool = True
    embed_scale: bool = False      # gemma-style sqrt(d) input scaling
    window: Optional[int] = None   # sliding window for attn_local layers
    max_seq: int = 524288
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_dense_d_ff: int = 0        # arctic: parallel dense-residual FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM ---
    ssm_state: int = 0             # mamba2 d_state
    ssm_heads: int = 0             # mlstm / mamba heads (0 => n_heads)
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0     # zamba2: shared attn block cadence
    # --- stub frontends (assignment carve-out) ---
    frontend: Optional[str] = None  # 'siglip_stub' | 'encodec_stub' | None
    prefix_len: int = 0            # VLM image-prefix length (bidirectional)
    num_classes: int = 0           # encoder classification head (ViT/BERT)
    scan_layers: bool = True       # lax.scan over repeated units (compile
                                   # time ~O(unit)); False = fully unrolled
    attn_block: int = 0            # >0: stream attention K/V in blocks of
                                   # this size (flash-style; §Perf H3)
    source: str = ""               # citation

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def block_kinds(self) -> tuple:
        if self.blocks:
            assert len(self.blocks) == self.n_layers, (
                f"{self.name}: blocks pattern length {len(self.blocks)} != "
                f"n_layers {self.n_layers}")
            return self.blocks
        return ("attn",) * self.n_layers

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.n_heads

    @property
    def scan_split(self) -> tuple:
        """(unit, n_units, n_tail): layers are stored as ``unit`` stacked
        trees of depth ``n_units`` (scanned — compile time independent of
        depth) plus ``n_tail`` unrolled remainder layers.  ``unit`` is the
        smallest period of the block-kind pattern (1 for uniform stacks,
        8 for xlstm's 7:1 mLSTM:sLSTM, 6 for zamba2/gemma3)."""
        kinds = self.block_kinds
        n = len(kinds)
        if not self.scan_layers:
            return n, 1, 0
        for u in range(1, n + 1):
            n_units = n // u
            if n_units == 0:
                break
            if all(kinds[i] == kinds[i % u] for i in range(n_units * u)):
                return u, n_units, n - n_units * u
        return n, 1, 0

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512, n_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (prompt contract:
        <=2 layers, d_model<=512, <=4 experts)."""
        assert d_model <= 512
        shrink = d_model / self.d_model
        def sc(v, lo=1):
            return max(lo, int(round(v * shrink)))
        n_heads = max(1, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        kinds = self.block_kinds[:n_layers]
        # keep family diversity in the reduced pattern (e.g. one mamba +
        # one shared_attn for zamba2; one mlstm + one slstm for xlstm)
        uniq = []
        for k in self.block_kinds:
            if k not in uniq:
                uniq.append(k)
        kinds = tuple((uniq * n_layers)[:n_layers])
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=min(64, d_model // n_heads),
            d_ff=sc(self.d_ff) if self.d_ff else 0,
            vocab_size=vocab,
            blocks=kinds,
            n_experts=min(self.n_experts, n_experts) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=sc(self.expert_d_ff) if self.expert_d_ff else 0,
            moe_dense_d_ff=sc(self.moe_dense_d_ff) if self.moe_dense_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.n_ssm_heads, 2) if self.ssm_heads or self.arch_type in ("ssm", "hybrid") else 0,
            window=min(self.window, 16) if self.window else None,
            prefix_len=min(self.prefix_len, 8) if self.prefix_len else 0,
            max_seq=4096,
            shared_attn_every=min(self.shared_attn_every, 2) if self.shared_attn_every else 0,
        )

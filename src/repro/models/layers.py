"""Pure-JAX neural layers: params are plain pytrees (nested dicts), every
layer is an ``init(key, ...) -> params`` / ``apply(params, x, ...)`` pair.

No flax/haiku — the framework owns its module system so that sharding
rules can address parameters by path (see ``repro.sharding.rules``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.float32):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * (d ** -0.5)).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x: jnp.ndarray, pos: jnp.ndarray, *, theta: float = 10000.0):
    """Rotary position embedding.  x (..., n, H, hd), pos (n,) or (..., n).

    For PRISM segment-mean columns the caller passes the segment *midpoint*
    as the column position (hardware-adaptation note in DESIGN.md §2: the
    paper's GPT-2 uses learned absolute embeddings which average into the
    means for free; RoPE models need a representative rotation per mean).
    """
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = pos.astype(jnp.float32)[..., :, None] * freq        # (..., n, half)
    angle = angle[..., :, None, :]                              # (..., n, 1, half)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

MLP_KINDS = ("gelu", "geglu", "swiglu", "relu")


def mlp_init(key, d: int, d_ff: int, kind: str, *, bias: bool = False,
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = kind in ("geglu", "swiglu")
    p = {"up": dense_init(k1, d, d_ff, bias=bias, dtype=dtype),
         "down": dense_init(k2, d_ff, d, bias=bias, dtype=dtype)}
    if gated:
        p["gate"] = dense_init(k3, d, d_ff, bias=bias, dtype=dtype)
    return p


def mlp(p, x, kind: str):
    if kind == "gelu":
        return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))
    if kind == "relu":
        return dense(p["down"], jax.nn.relu(dense(p["up"], x)))
    up = dense(p["up"], x)
    gate = dense(p["gate"], x)
    act = jax.nn.gelu(gate, approximate=True) if kind == "geglu" else jax.nn.silu(gate)
    return dense(p["down"], act * up)


# --------------------------------------------------------------------------
# attention layer (PRISM-aware through the SeqContext protocol)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    bias: bool = False
    rope_theta: float | None = 10000.0   # None => no rotary (learned/abs pos)
    qk_norm: bool = False
    logit_softcap: float | None = None
    window: int | None = None            # sliding-window layer (gemma3 local)
    causal: bool = True


def attn_init(key, s: AttnSpec, dtype=jnp.float32):
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    p = {
        "wq": dense_init(kq, s.d_model, s.n_heads * s.head_dim, bias=s.bias, dtype=dtype),
        "wk": dense_init(kk, s.d_model, s.n_kv_heads * s.head_dim, bias=s.bias, dtype=dtype),
        "wv": dense_init(kv, s.d_model, s.n_kv_heads * s.head_dim, bias=s.bias, dtype=dtype),
        "wo": dense_init(ko, s.n_heads * s.head_dim, s.d_model, bias=s.bias, dtype=dtype),
    }
    if s.qk_norm:
        p["qnorm"] = norm_init(s.head_dim, "rmsnorm", dtype)
        p["knorm"] = norm_init(s.head_dim, "rmsnorm", dtype)
    return p


def attn_project_q(p, s: AttnSpec, x, row_pos):
    b, n, _ = x.shape
    q = dense(p["wq"], x).reshape(b, n, s.n_heads, s.head_dim)
    if s.qk_norm:
        q = norm(p["qnorm"], q)
    if s.rope_theta is not None:
        q = rope(q, row_pos, theta=s.rope_theta)
    return q


def attn_project_kv(p, s: AttnSpec, x_hat, col_pos):
    b, m, _ = x_hat.shape
    k = dense(p["wk"], x_hat).reshape(b, m, s.n_kv_heads, s.head_dim)
    v = dense(p["wv"], x_hat).reshape(b, m, s.n_kv_heads, s.head_dim)
    if s.qk_norm:
        k = norm(p["knorm"], k)
    if s.rope_theta is not None:
        k = rope(k, col_pos, theta=s.rope_theta)
    return k, v


def attn_output(p, o):
    b, n, h, hd = o.shape
    return dense(p["wo"], o.reshape(b, n, h * hd))

"""Mixture-of-Experts FFN with sort-based capacity dispatch.

The same dispatch runs everywhere; only the *exchange* differs:
  * FullContext: experts are local — exchange is the identity.
  * ShardedPrismContext: experts are sharded over the ``model`` mesh axis —
    exchange is a pair of ``lax.all_to_all``s (dispatch and return), the
    canonical expert-parallel pattern.

Routing: softmax router, top-k, capacity ``C = ceil(T·k/E · capacity_factor)``
per expert per device; overflow tokens are dropped (their combine weight
contribution is zero — the residual path carries them).  The standard
load-balance auxiliary loss is returned for training.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dense, mlp_init, mlp


def moe_init(key, d: int, n_experts: int, d_ff: int, kind: str,
             *, dense_d_ff: int = 0, dtype=jnp.float32):
    kr, ke, kd = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, n_experts)
    experts = jax.vmap(
        lambda k: mlp_init(k, d, d_ff, kind, dtype=dtype))(ekeys)
    p = {"router": dense_init(kr, d, n_experts, dtype=dtype),
         "experts": experts}
    if dense_d_ff:
        p["dense_mlp"] = mlp_init(kd, d, dense_d_ff, kind, dtype=dtype)
    return p


def route(router_p, x_flat, top_k: int, n_experts: int):
    """Returns (probs (T,k), idx (T,k), aux_loss scalar)."""
    logits = dense(router_p, x_flat).astype(jnp.float32)    # (T, E)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(probs_full, top_k)
    # load-balance loss (Switch-style): E * sum_e f_e * P_e
    t = x_flat.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / (t * top_k)
    p_mean = probs_full.mean(axis=0)
    aux = n_experts * jnp.sum(f * p_mean)
    return probs.astype(x_flat.dtype), idx, aux


def capacity(t: int, top_k: int, n_experts: int, factor: float) -> int:
    return max(1, math.ceil(t * top_k / n_experts * factor))


def dispatch_indices(idx: jnp.ndarray, n_experts: int, cap: int):
    """Sort-based slotting: token-assignment -> (expert, slot) coordinates.

    idx: (T, k) expert ids.  Returns (expert (Tk,), slot (Tk,), keep (Tk,),
    token (Tk,)) with slot < cap where keep.
    """
    t, k = idx.shape
    flat_e = idx.reshape(-1)                       # (Tk,)
    token = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    rank_sorted = jnp.arange(t * k) - first[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap
    return flat_e, rank, keep, token


def moe_apply(p, x, cfg, ctx):
    """x: (B, N, D) -> (y, aux_loss).  cfg is a ModelConfig."""
    b, n, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    x_flat = x.reshape(b * n, d)
    t = b * n
    probs, idx, aux = route(p["router"], x_flat, k, e)
    cap = capacity(t, k, e, cfg.capacity_factor)

    flat_e, slot, keep, token = dispatch_indices(idx, e, cap)
    buf = jnp.zeros((e, cap, d), x.dtype)
    src = jnp.where(keep[:, None], x_flat[token], 0.0)
    buf = buf.at[flat_e, slot].add(src)            # dropped tokens add 0

    # exchange -> (E_local, S, D), S = cap * n_model_shards.  Under
    # shard_map the expert params are already the local (E_local, ...)
    # shard, so the vmap below lines up in both contexts.
    buf_local, undo = ctx.expert_exchange(buf)

    def one_expert(ep, xe):
        return mlp(ep, xe, cfg.mlp_kind)
    y_local = jax.vmap(one_expert)(p["experts"], buf_local)
    y_local = ctx.expert_reduce(y_local)           # expert-TP partials

    y_buf = undo(y_local)                          # (E, cap, D)

    w = jnp.where(keep, probs.reshape(-1), 0.0)
    y_tok = y_buf[flat_e, slot] * w[:, None].astype(x.dtype)
    y_flat = jnp.zeros_like(x_flat).at[token].add(y_tok)
    y = y_flat.reshape(b, n, d)

    if "dense_mlp" in p:                           # arctic dense residual
        y = y + ctx.ffn_reduce(mlp(p["dense_mlp"], x, cfg.mlp_kind))
    return y, aux

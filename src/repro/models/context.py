"""SeqContext — how a model's sequence-mixing layers see the sequence.

One model implementation serves three execution styles:

  * ``FullContext``      — whole sequence on one executor (smoke tests,
                           single-device baseline, the paper's "No partition").
  * ``SimulatedContext`` — the paper's P-device protocol simulated on one
                           chip: partitions stacked into the batch axis,
                           Segment-Means exchange materialized exactly as the
                           per-device math (modes prism/voltage/duplicate).
                           Used for accuracy experiments and as the oracle
                           for the sharded path.
  * ``ShardedPrismContext`` (repro.sharding.context) — the production path:
                           the same math under ``shard_map`` where the
                           exchange is a ``lax.all_gather`` of segment means
                           over the ``model`` mesh axis.

The context contract for attention layers:

    xq, akv = ctx.augment(x, spec)     # query source + augmented K/V view
    ... attention(xq ..., akv.x_hat ..., akv.g, akv.mask) ...
    out = ctx.finalize(out)            # back to the caller's layout

and for linear-recurrence (SSM) layers:

    prefix = ctx.state_handoff(summaries)   # cross-chunk/device prefix states
    gathered = ctx.gather_sequence(x)       # escape hatch (sLSTM; voltage)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..core.segment_means import segment_means, segment_sizes, segment_bounds
from ..core.masks import visibility, visibility_np
from ..core.protocol import PrismConfig
from .layers import AttnSpec


@dataclass(frozen=True)
class AugmentedKV:
    x_hat: jnp.ndarray                 # (B', M, D) K/V source
    g: Optional[jnp.ndarray]           # broadcastable to (B',1,Nq,M) or None
    mask: Optional[jnp.ndarray]        # bool, (Nq,M) or (B',1,Nq,M)
    row_pos: jnp.ndarray               # (Nq,) or (B',Nq) — for q RoPE
    col_pos: jnp.ndarray               # (M,)  or (B',M)  — for k RoPE
    # per-column global position ranges (M,), when the mask is purely
    # positional — lets the Pallas kernel re-derive visibility in-VMEM
    # instead of consuming the materialized (Nq, M) mask.  None when the
    # mask carries extra structure (ring-halo clipping, batched masks).
    col_lo: Optional[jnp.ndarray] = None
    col_hi: Optional[jnp.ndarray] = None


class SeqContext:
    def augment(self, x, spec: AttnSpec):
        raise NotImplementedError

    def finalize(self, out):
        return out

    # ---- linear-recurrence (SSM) cross-device handoff -------------------
    def state_handoff(self, log_a_tot, u_tot):
        """Initial state entering this executor's chunk for a linear
        recurrence ``S' = a·S + u``.  ``log_a_tot (B,H)`` / ``u_tot
        (B,H,dk,dv)`` summarize the local chunk.  Single-executor contexts
        own the whole sequence, so the incoming state is zero; the sharded
        context computes an exclusive prefix over the ``model`` axis."""
        return jnp.zeros_like(u_tot)

    # ---- sequence escape hatches ----------------------------------------
    def gather_sequence(self, x):
        """Full sequence view (sLSTM; inherently sequential layers)."""
        return x

    def take_local(self, y_full):
        """Inverse of gather_sequence: slice this executor's span."""
        return y_full

    def prev_tail(self, x, size: int):
        """Last ``size`` positions of the *preceding* chunk (causal-conv /
        sliding-window halo).  Zeros at the true sequence start."""
        return jnp.zeros(x.shape[:-2] + (size,) + x.shape[-1:], x.dtype)

    def last_shard(self, x):
        """Value held by the executor owning the END of the sequence,
        broadcast to all (decode-cache capture).  Identity when one
        executor owns the whole sequence."""
        return x

    # ---- MoE expert exchange ---------------------------------------------
    def expert_exchange(self, buf):
        """(E, cap, D) -> (E_local, S, D) plus the inverse for the outputs.
        Identity when experts are local."""
        return buf, lambda y: y

    def expert_reduce(self, y):
        """Sum expert-TP down-projection partials (identity unless the
        per-expert d_ff dim is sharded — decode expert-TP)."""
        return y

    def ffn_reduce(self, y):
        """Sum Megatron-TP FFN partials (identity unless the dense FFN is
        column/row-split over 'model' — decode TP; used for the MoE
        dense-residual branch)."""
        return y


# --------------------------------------------------------------------------


class FullContext(SeqContext):
    """Whole sequence visible; standard masks; no compression."""

    def __init__(self, *, start: int = 0, prefix_len: int = 0):
        self.start = start
        self.prefix_len = prefix_len

    def augment(self, x, spec: AttnSpec):
        n = x.shape[-2]
        pos = jnp.arange(n) + self.start
        mask = visibility(pos, pos, pos, causal=spec.causal,
                          prefix_len=self.prefix_len, window=spec.window)
        return x, AugmentedKV(x, None, mask, pos, pos)


class SimulatedContext(SeqContext):
    """Paper-faithful P-device simulation on one executor.

    Requires N % P == 0 so partitions stack; the ragged general case is
    covered by `repro.core.protocol.device_views` (used in tests/evals with
    a python loop).  Partitions are folded into the batch axis:
    x (B, N, D) -> xq (B*P, N/P, D).
    """

    def __init__(self, cfg: PrismConfig, *, prefix_len: int = 0):
        self.cfg = cfg
        self.prefix_len = prefix_len
        self._b = None  # remembered for finalize

    def augment(self, x, spec: AttnSpec):
        cfg = self.cfg
        b, n, d = x.shape
        p = cfg.P
        assert n % p == 0, "SimulatedContext needs N % P == 0"
        npart = n // p
        self._b = b
        xp = x.reshape(b, p, npart, d)
        xq = xp.reshape(b * p, npart, d)
        row_pos = (np.arange(p)[:, None] * npart + np.arange(npart))  # (P, Np)

        if cfg.mode == "voltage" or spec.window is not None:
            # voltage: full exchange.  Sliding-window layers likewise use the
            # exact window (PRISM means are out-of-window by construction).
            x_hat = jnp.broadcast_to(x[:, None], (b, p, n, d)).reshape(b * p, n, d)
            col = np.arange(n)
            masks = np.stack([
                visibility_np(rp, col, col, causal=spec.causal,
                              prefix_len=self.prefix_len,
                              window=spec.window)
                for rp in row_pos])
            mask = jnp.asarray(np.tile(masks, (b, 1, 1)))[:, None]
            akv = AugmentedKV(
                x_hat, None, mask,
                jnp.asarray(np.tile(row_pos, (b, 1))),
                jnp.broadcast_to(jnp.asarray(col), (b * p, n)))
            return xq, akv

        L = cfg.landmarks(n)
        z = segment_means(xp, L)                       # (B, P, L, D)
        sizes = segment_sizes(npart, L)                # same for all partitions
        mids, los, his = [], [], []
        for q in range(p):
            lo, hi = segment_bounds(npart, L, offset=q * npart)
            los.append(lo); his.append(hi)
            mids.append((lo + hi) / 2.0)

        x_hats, gs, masks, col_poss = [], [], [], []
        for pi in range(p):
            others = [q for q in range(p) if q != pi]
            remote = jnp.concatenate([z[:, q] for q in others], axis=-2)
            x_hats.append(jnp.concatenate([xp[:, pi], remote], axis=-2))
            if cfg.mode == "prism_nodup":          # Table II 'No' column
                g = np.ones(npart + len(others) * L)
            else:
                g = np.concatenate([np.ones(npart)]
                                   + [sizes for _ in others])
            gs.append(g)
            c_lo = np.concatenate([np.arange(npart) + pi * npart]
                                  + [los[q] for q in others])
            c_hi = np.concatenate([np.arange(npart) + pi * npart]
                                  + [his[q] for q in others])
            col_poss.append(np.concatenate(
                [np.arange(npart) + pi * npart] + [mids[q] for q in others]))
            masks.append(visibility_np(
                row_pos[pi], c_lo, c_hi,
                causal=spec.causal, prefix_len=self.prefix_len, window=None))

        x_hat = jnp.stack(x_hats, axis=1)              # (B, P, M, D)
        m = x_hat.shape[-2]
        x_hat = x_hat.reshape(b * p, m, d)
        g = jnp.asarray(np.tile(np.stack(gs), (b, 1)))[:, None, None, :]
        mask = jnp.asarray(np.tile(np.stack(masks), (b, 1, 1)))[:, None]
        akv = AugmentedKV(
            x_hat, g, mask,
            jnp.asarray(np.tile(row_pos, (b, 1))),
            jnp.asarray(np.tile(np.stack(col_poss), (b, 1))))
        return xq, akv

    def finalize(self, out):
        bp, npart, d = out.shape
        b = self._b
        return out.reshape(b, bp // b, npart, d).reshape(b, npart * bp // b, d)


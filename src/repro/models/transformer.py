"""Composable Transformer stack covering every assigned architecture family.

``init(cfg, key)`` builds a parameter pytree; ``forward(cfg, params, ...)``
runs it under any ``SeqContext`` (single-device, simulated-P, or sharded).
Heterogeneous per-layer block kinds (attn / attn_local / moe / mlstm /
slstm / mamba / shared_attn) come from ``cfg.block_kinds``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .context import SeqContext, FullContext
from .layers import (AttnSpec, attn_init, attn_project_q, attn_project_kv,
                     attn_output, dense_init, dense, embedding_init, embed,
                     mlp_init, mlp, norm_init, norm)
from .moe import moe_init, moe_apply
from .ssm import (mlstm_init, mlstm_apply, slstm_init, slstm_apply,
                  mamba2_init, mamba2_apply)
from ..core.attention import prism_attention


# --------------------------------------------------------------------------
# per-layer specs
# --------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, kind: str) -> AttnSpec:
    local = kind == "attn_local"
    theta = None
    if cfg.pos == "rope":
        theta = cfg.rope_theta_local if local else cfg.rope_theta
    return AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, bias=cfg.attn_bias, rope_theta=theta,
        qk_norm=cfg.qk_norm, logit_softcap=cfg.logit_softcap,
        window=cfg.window if local else None, causal=cfg.causal,
    )


def block_init(cfg: ModelConfig, kind: str, key, dtype):
    ks = jax.random.split(key, 4)
    if kind in ("attn", "attn_local"):
        p = {"ln1": norm_init(cfg.d_model, cfg.norm_kind, dtype),
             "attn": attn_init(ks[0], attn_spec(cfg, kind), dtype)}
        if cfg.d_ff:
            if not cfg.parallel_block:
                p["ln2"] = norm_init(cfg.d_model, cfg.norm_kind, dtype)
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                                bias=cfg.attn_bias, dtype=dtype)
        return p
    if kind == "moe":
        return {"ln1": norm_init(cfg.d_model, cfg.norm_kind, dtype),
                "attn": attn_init(ks[0], attn_spec(cfg, kind), dtype),
                "ln2": norm_init(cfg.d_model, cfg.norm_kind, dtype),
                "moe": moe_init(ks[1], cfg.d_model, cfg.n_experts,
                                cfg.expert_d_ff, cfg.mlp_kind,
                                dense_d_ff=cfg.moe_dense_d_ff, dtype=dtype)}
    if kind == "mlstm":
        return {"ln": norm_init(cfg.d_model, cfg.norm_kind, dtype),
                "cell": mlstm_init(ks[0], cfg.d_model, cfg.n_ssm_heads,
                                   cfg.ssm_expand, dtype)}
    if kind == "slstm":
        return {"ln": norm_init(cfg.d_model, cfg.norm_kind, dtype),
                "cell": slstm_init(ks[0], cfg.d_model, cfg.n_ssm_heads, dtype)}
    if kind == "mamba":
        return {"ln": norm_init(cfg.d_model, cfg.norm_kind, dtype),
                "cell": mamba2_init(ks[0], cfg.d_model, cfg.n_ssm_heads,
                                    cfg.ssm_state, cfg.ssm_expand,
                                    cfg.ssm_conv, dtype)}
    if kind == "shared_attn":
        return {}          # uses params["shared"] (zamba2 weight sharing)
    raise ValueError(f"unknown block kind {kind!r}")


def stack_layers(cfg: ModelConfig, layers: list) -> dict:
    """Per-layer trees -> {'scan': [u stacked trees], 'tail': [...]} —
    the storage layout for scan-over-layers (compile time ~ O(unit), not
    O(depth); see ModelConfig.scan_split)."""
    u, n_units, _ = cfg.scan_split
    scan = []
    for j in range(u):
        group = [layers[i * u + j] for i in range(n_units)]
        scan.append(jax.tree.map(lambda *xs: jnp.stack(xs), *group)
                    if jax.tree.leaves(group[0]) else group[0])
    return {"scan": scan, "tail": layers[n_units * u:]}


def layer_slice(stack, i: int):
    """i-th layer of a stacked tree (host-side oracle path)."""
    return jax.tree.map(lambda t: t[i], stack)


def iter_layers(cfg: ModelConfig, params):
    """Yield (kind, layer_tree) in depth order from the stacked layout."""
    u, n_units, n_tail = cfg.scan_split
    kinds = cfg.block_kinds
    for i in range(n_units):
        for j in range(u):
            stack = params["scan"][j]
            yield kinds[j], (layer_slice(stack, i)
                             if jax.tree.leaves(stack) else stack)
    for t, tree in enumerate(params["tail"]):
        yield kinds[n_units * u + t], tree


def init(cfg: ModelConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, cfg.n_layers + 5)
    layers = [block_init(cfg, kind, keys[i], dtype)
              for i, kind in enumerate(cfg.block_kinds)]
    params = {**stack_layers(cfg, layers),
              "final_norm": norm_init(cfg.d_model, cfg.norm_kind, dtype)}
    if cfg.vocab_size:
        params["embed"] = embedding_init(keys[-1], cfg.vocab_size,
                                         cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[-2], cfg.d_model,
                                           cfg.vocab_size, dtype=dtype)
    if cfg.pos == "learned":
        params["pos_embed"] = embedding_init(keys[-3], cfg.max_seq,
                                             cfg.d_model, dtype)
    if "shared_attn" in cfg.block_kinds:
        params["shared"] = {
            "ln1": norm_init(cfg.d_model, cfg.norm_kind, dtype),
            "attn": attn_init(keys[-4], attn_spec(cfg, "attn"), dtype),
            "ln2": norm_init(cfg.d_model, cfg.norm_kind, dtype),
            "mlp": mlp_init(keys[-5], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                            dtype=dtype)}
    if cfg.num_classes:
        params["head"] = dense_init(keys[-2], cfg.d_model, cfg.num_classes,
                                    bias=True, dtype=dtype)
    if cfg.frontend:
        # stub modality projector (assignment carve-out): identity-sized
        # linear from "frontend embedding" space into the backbone.
        params["frontend_proj"] = dense_init(keys[-3], cfg.d_model,
                                             cfg.d_model, dtype=dtype)
    return params


# --------------------------------------------------------------------------
# sublayers
# --------------------------------------------------------------------------

def _softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


def attn_sublayer(p, x, ctx: SeqContext, spec: AttnSpec, cfg: ModelConfig):
    """PRISM-aware attention through the SeqContext protocol.

    Segment means are exchanged on the *block input* (pre-norm residual
    stream) — the quantity a real deployment transmits once per block —
    and the receiving side applies its local LayerNorm to the augmented
    matrix (LN of mean, matching a device that norms what it received)."""
    xq, akv = ctx.augment(x, spec)
    xq_n = norm(p["ln1"], xq, cfg.norm_kind)
    xh_n = norm(p["ln1"], akv.x_hat, cfg.norm_kind)
    q = attn_project_q(p["attn"], spec, xq_n, akv.row_pos)
    k, v = attn_project_kv(p["attn"], spec, xh_n, akv.col_pos)
    o = prism_attention(q, k, v, g=akv.g, mask=akv.mask,
                        block=cfg.attn_block)
    o = attn_output(p["attn"], o)
    if cfg.parallel_block:
        o = o + mlp(p["mlp"], xq_n, cfg.mlp_kind)
    return ctx.finalize(o), xq_n


def block_apply(cfg: ModelConfig, kind: str, p, shared, x, ctx: SeqContext,
                chunk: int = 128):
    """One residual block.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "moe"):
        spec = attn_spec(cfg, kind)
        o, _ = attn_sublayer(p, x, ctx, spec, cfg)
        x = x + o
        if cfg.parallel_block:
            return x, aux     # mlp fused in the parallel branch
        if kind == "moe":
            y, aux = moe_apply(p["moe"], norm(p["ln2"], x, cfg.norm_kind),
                               cfg, ctx)
            x = x + y
        elif cfg.d_ff:
            x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind),
                        cfg.mlp_kind)
        return x, aux
    if kind == "shared_attn":
        spec = attn_spec(cfg, "attn")
        o, _ = attn_sublayer(shared, x, ctx, spec, cfg)
        x = x + o
        x = x + mlp(shared["mlp"], norm(shared["ln2"], x, cfg.norm_kind),
                    cfg.mlp_kind)
        return x, aux
    if kind == "mlstm":
        x = x + mlstm_apply(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                            heads=cfg.n_ssm_heads, ctx=ctx, chunk=chunk)
        return x, aux
    if kind == "slstm":
        x = x + slstm_apply(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                            heads=cfg.n_ssm_heads, ctx=ctx)
        return x, aux
    if kind == "mamba":
        x = x + mamba2_apply(p["cell"], norm(p["ln"], x, cfg.norm_kind),
                             heads=cfg.n_ssm_heads, d_state=cfg.ssm_state,
                             expand=cfg.ssm_expand, conv=cfg.ssm_conv,
                             ctx=ctx, chunk=chunk)
        return x, aux
    raise ValueError(kind)


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, tokens=None, embeds=None,
                 pos_start=0):
    """tokens (B, N) and/or stub-frontend embeds -> x (B, N, D).

    VLM: embeds are the image-patch prefix — they OVERWRITE the first
    ``prefix_len`` token positions (tokens there are placeholders), the
    same convention the sharded runtime uses.  Audio: embeds are the
    whole frame sequence (no tokens)."""
    if tokens is not None:
        x = embed(params["embed"], tokens)
        if embeds is not None and cfg.arch_type == "vlm":
            fe = dense(params["frontend_proj"], embeds)
            x = jnp.concatenate([fe.astype(x.dtype),
                                 x[:, cfg.prefix_len:]], axis=1)
    else:
        x = (dense(params["frontend_proj"], embeds) if cfg.frontend
             else embeds)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    n = x.shape[1]
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"]["table"], pos_start, n).astype(x.dtype)
    elif cfg.pos == "sincos":
        x = x + sincos_embed(n, cfg.d_model, pos_start).astype(x.dtype)
    return x


def sincos_embed(n: int, d: int, start=0):
    """Parameter-free sinusoidal positions (musicgen; long-context safe).
    ``start`` may be a traced scalar (sharded path)."""
    pos = (jnp.arange(n, dtype=jnp.float32)
           + jnp.asarray(start, jnp.float32))[:, None]
    half = d // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def forward(cfg: ModelConfig, params, tokens=None, *, embeds=None,
            ctx: SeqContext | None = None, chunk: int = 128):
    """Returns (logits_or_features, aux_losses)."""
    ctx = ctx or FullContext(prefix_len=cfg.prefix_len)
    x = embed_inputs(cfg, params, tokens, embeds)
    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared")
    for kind, p in iter_layers(cfg, params):
        x, aux = block_apply(cfg, kind, p, shared, x, ctx, chunk=chunk)
        aux_total = aux_total + aux
    x = norm(params["final_norm"], x, cfg.norm_kind)
    aux = {"moe_aux": aux_total}
    if cfg.num_classes:                    # encoder classification (ViT/BERT)
        pooled = x[:, 0]                   # CLS token
        return dense(params["head"], pooled), aux
    if cfg.vocab_size:
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T.astype(x.dtype)
        else:
            logits = dense(params["lm_head"], x)
        return _softcap(logits, cfg.logit_softcap), aux
    return x, aux

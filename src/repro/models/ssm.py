"""SSM / recurrent sequence mixers: mLSTM, sLSTM (xLSTM [arXiv:2405.04517])
and Mamba2 / SSD (zamba2 [arXiv:2411.15242]).

All linear recurrences share one chunkwise algorithm
(``chunked_linear_attention``): within a chunk the recurrence

    S_t = f_t · S_{t-1} + i_t · k_t v_tᵀ ,   y_t = q_t · S_t

is evaluated as decay-masked attention (q kᵀ ⊙ Γ) v — O(c²) per chunk —
while chunk-to-chunk state flows through a tiny (dk×dv) summary.  Under
sequence partitioning the *device-to-device* state handoff goes through
``ctx.state_handoff`` — a constant-size exchange, which is the PRISM
adaptation for recurrent blocks (DESIGN.md §6): the state *is* the
summary, no Segment Means needed.

sLSTM's recurrence passes through a nonlinearity, so it cannot be
chunk-parallelized; the sharded path gathers the full (pre-activation)
sequence and scans locally (DESIGN.md §6 records this as
PRISM-inapplicable).

Numerics note (recorded in DESIGN.md): input/forget gates use
sigmoid (log-sigmoid decays), not xLSTM's exponential-gating stabilizer —
the chunked math is identical, the gate range is narrower.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, dense, norm_init, norm


# --------------------------------------------------------------------------
# shared chunkwise linear recurrence
# --------------------------------------------------------------------------

def chunked_linear_attention(q, k, v, log_f, gate_i, *, chunk: int, ctx,
                             normalize: bool = False,
                             return_state: bool = False):
    """q,k: (B,N,H,dk)  v: (B,N,H,dv)  log_f, gate_i: (B,N,H), log_f <= 0.

    Returns y (B,N,H,dv).  With ``normalize`` a ones-column is appended to v
    (the mLSTM normalizer n_t) and the output is divided by max(|q·n|, 1).

    ``return_state``: additionally return the recurrence state *after the
    final token of the global sequence* (B,H,dk,dv[+1]) — the decode cache.
    Under sharding each executor computes its local end-state and the
    context's ``last_shard`` broadcasts the final shard's value.
    """
    b, n, h, dk = q.shape
    dv = v.shape[-1]
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
        dv += 1
    chunk = min(chunk, n)
    assert n % chunk == 0, f"N={n} not divisible by chunk={chunk}"
    nc = n // chunk

    def r(x):
        return x.reshape(b, nc, chunk, *x.shape[2:])

    qc, kc, vc = r(q), r(k), r(v)
    lf, gi = r(log_f), r(gate_i)

    a = jnp.cumsum(lf, axis=2)                       # (B,nc,c,H) inclusive
    # intra-chunk: w_{tτ} = exp(a_t - a_τ) · i_τ for τ <= t
    diff = a[:, :, :, None, :] - a[:, :, None, :, :]             # (B,nc,c,c,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    w = w * gi[:, :, None, :, :]
    scores = jnp.einsum("bnthd,bnshd->bntsh", qc, kc)
    y = jnp.einsum("bntsh,bntsh,bnshv->bnthv",
                   scores, w.astype(scores.dtype), vc)

    # chunk summaries: logA_j = a_end; U_j = Σ exp(a_end - a_τ) i_τ k_τ v_τᵀ
    # (state path in f32: the associative scan mixes exp(f32 decays) into
    # the state, and bf16 accumulation both loses precision and trips
    # lax.concatenate dtype checks inside associative_scan)
    a_end = a[:, :, -1]                                          # (B,nc,H)
    wu = jnp.exp(a_end[:, :, None] - a) * gi                     # (B,nc,c,H)
    u = jnp.einsum("bnsh,bnshd,bnshv->bnhdv", wu.astype(kc.dtype), kc, vc
                   ).astype(jnp.float32)

    # local prefix over chunks (exclusive): S_in_j
    def combine(x1, x2):
        la1, u1 = x1
        la2, u2 = x2
        return la1 + la2, jnp.exp(la2)[..., None, None] * u1 + u2
    la_s, u_s = jax.lax.associative_scan(combine, (a_end, u), axis=1)
    s_in = jnp.concatenate(
        [jnp.zeros_like(u_s[:, :1]), u_s[:, :-1]], axis=1)       # (B,nc,H,dk,dv)
    la_in = jnp.concatenate(
        [jnp.zeros_like(la_s[:, :1]), la_s[:, :-1]], axis=1)

    # cross-device prefix: summarize the whole local span, ask the context
    log_a_tot = la_s[:, -1]                                      # (B,H)
    u_tot = u_s[:, -1]                                           # (B,H,dk,dv)
    s0 = ctx.state_handoff(log_a_tot, u_tot)                     # (B,H,dk,dv)

    # state entering chunk j (global) = exp(la_in_j)·s0 + s_in_j
    s_glob = jnp.exp(la_in)[..., None, None] * s0.astype(jnp.float32)[:, None] \
        + s_in
    y = (y.astype(jnp.float32)
         + jnp.einsum("bnth,bnthd,bnhdv->bnthv",
                      jnp.exp(a), qc.astype(jnp.float32), s_glob)
         ).astype(v.dtype)
    y = y.reshape(b, n, h, dv)

    if normalize:
        y, nrm = y[..., :-1], y[..., -1:]
        y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    if return_state:
        s_end = jnp.exp(log_a_tot)[..., None, None] * s0 + u_tot  # (B,H,dk,dv)
        return y, ctx.last_shard(s_end)
    return y


# --------------------------------------------------------------------------
# mLSTM block (xLSTM)
# --------------------------------------------------------------------------

def mlstm_init(key, d: int, heads: int, expand: int, dtype=jnp.float32):
    d_in = d * expand
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d, 2 * d_in, dtype=dtype),
        "wq": dense_init(ks[1], d_in, d_in, dtype=dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype=dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype=dtype),
        "gates": dense_init(ks[4], d_in, 2 * heads, bias=True, dtype=dtype),
        "hnorm": norm_init(d_in // heads, "rmsnorm", dtype),
        "down": dense_init(ks[5], d_in, d, dtype=dtype),
    }


def mlstm_apply(p, x, *, heads: int, ctx, chunk: int = 128,
                return_state: bool = False):
    b, n, d = x.shape
    xz = dense(p["up"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)
    d_in = x_in.shape[-1]
    hd = d_in // heads

    def split_heads(t):
        return t.reshape(b, n, heads, hd)
    q = split_heads(dense(p["wq"], x_in)) * (hd ** -0.5)
    k = split_heads(dense(p["wk"], x_in))
    v = split_heads(dense(p["wv"], x_in))
    gp = dense(p["gates"], x_in).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gp, 2, axis=-1)                     # (B,N,H)
    log_f = jax.nn.log_sigmoid(f_pre + 1.0)   # forget bias -> long memory
    gate_i = jax.nn.sigmoid(i_pre)

    h = chunked_linear_attention(q, k, v, log_f, gate_i,
                                 chunk=chunk, ctx=ctx, normalize=True,
                                 return_state=return_state)
    if return_state:
        h, state = h
    h = norm(p["hnorm"], h)
    h = h.reshape(b, n, d_in) * jax.nn.silu(z)
    y = dense(p["down"], h)
    return (y, state) if return_state else y


def mlstm_decode(p, x, state, *, heads: int):
    """One-token decode: x (B,1,D), state (B,H,dk,dv+1) -> (y, state')."""
    b, _, d = x.shape
    xz = dense(p["up"], x[:, 0])
    x_in, z = jnp.split(xz, 2, axis=-1)
    d_in = x_in.shape[-1]
    hd = d_in // heads

    def heads_of(t):
        return t.reshape(b, heads, hd)
    q = heads_of(dense(p["wq"], x_in)) * (hd ** -0.5)
    k = heads_of(dense(p["wk"], x_in))
    v = heads_of(dense(p["wv"], x_in))
    gp = dense(p["gates"], x_in).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gp, 2, axis=-1)                     # (B,H)
    f = jax.nn.sigmoid(f_pre + 1.0)
    i = jax.nn.sigmoid(i_pre)
    v1 = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v1) * i[..., None, None]
    state = f[..., None, None].astype(state.dtype) * state + kv
    y = jnp.einsum("bhd,bhdv->bhv", q, state.astype(q.dtype))
    y, nrm = y[..., :-1], y[..., -1:]
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)
    h = norm(p["hnorm"], y)
    h = h.reshape(b, d_in) * jax.nn.silu(z)
    return dense(p["down"], h)[:, None], state


# --------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential; PRISM-inapplicable (DESIGN.md §6)
# --------------------------------------------------------------------------

def slstm_init(key, d: int, heads: int, dtype=jnp.float32):
    hd = d // heads
    ks = jax.random.split(key, 4)
    return {
        "wx": dense_init(ks[0], d, 4 * d, bias=True, dtype=dtype),
        # block-diagonal recurrent weights, one (hd, hd) block per head/gate
        "r": (jax.random.normal(ks[1], (4, heads, hd, hd)) * (hd ** -0.5)
              ).astype(dtype),
        "hnorm": norm_init(d, "rmsnorm", dtype),
        "down": dense_init(ks[2], d, d, dtype=dtype),
    }


def _slstm_step(r, carry, gates_t):
    c, nrm, h = carry
    rec = jnp.einsum("ghij,bhj->bghi", r, h)         # (B,4,H,hd)
    zt, it, ft, ot = [gates_t[:, i] + rec[:, i] for i in range(4)]
    zt = jnp.tanh(zt)
    it = jax.nn.sigmoid(it)
    ft = jax.nn.sigmoid(ft + 1.0)
    ot = jax.nn.sigmoid(ot)
    c = ft * c + it * zt
    nrm = ft * nrm + it
    h = ot * c / jnp.maximum(jnp.abs(nrm), 1.0)
    return (c, nrm, h), h


def slstm_apply(p, x, *, heads: int, ctx, return_state: bool = False):
    b, n, d = x.shape
    hd = d // heads
    x_full = ctx.gather_sequence(x)                  # (B, N_full, D)
    nf = x_full.shape[1]
    pre = dense(p["wx"], x_full).reshape(b, nf, 4, heads, hd)
    r = p["r"].astype(jnp.float32)

    z0 = jnp.zeros((b, heads, hd), jnp.float32)
    carry, hs = jax.lax.scan(
        lambda c, g: _slstm_step(r, c, g),
        (z0, z0, z0), jnp.moveaxis(pre.astype(jnp.float32), 1, 0))
    h_full = jnp.moveaxis(hs, 0, 1).reshape(b, nf, d).astype(x.dtype)
    h = ctx.take_local(h_full)
    h = norm(p["hnorm"], h)
    y = dense(p["down"], h)
    if return_state:
        return y, jnp.stack(carry, axis=1)           # (B, 3, H, hd)
    return y


def slstm_decode(p, x, state, *, heads: int):
    """x (B,1,D), state (B,3,H,hd) f32 -> (y, state')."""
    b, _, d = x.shape
    hd = d // heads
    pre = dense(p["wx"], x[:, 0]).reshape(b, 4, heads, hd).astype(jnp.float32)
    r = p["r"].astype(jnp.float32)
    carry = tuple(state[:, i] for i in range(3))
    carry, h = _slstm_step(r, carry, pre)
    h = norm(p["hnorm"], h.reshape(b, d).astype(x.dtype))
    return dense(p["down"], h)[:, None], jnp.stack(carry, axis=1)


# --------------------------------------------------------------------------
# Mamba2 / SSD block (zamba2)
# --------------------------------------------------------------------------

def mamba2_init(key, d: int, heads: int, d_state: int, expand: int,
                conv: int, dtype=jnp.float32):
    d_in = d * expand
    ks = jax.random.split(key, 5)
    return {
        # fused in-proj: [z (d_in), x (d_in), B (d_state), C (d_state), dt (H)]
        "in": dense_init(ks[0], d, 2 * d_in + 2 * d_state + heads, dtype=dtype),
        "conv": (jax.random.normal(ks[1], (conv, d_in)) * (conv ** -0.5)
                 ).astype(dtype),
        "a_log": jnp.zeros((heads,), jnp.float32),   # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "d_skip": jnp.ones((heads,), dtype),
        "ynorm": norm_init(d_in, "rmsnorm", dtype),
        "out": dense_init(ks[2], d_in, d, dtype=dtype),
    }


def _mamba_proj_split(p, x, d_in, d_state):
    proj = dense(p["in"], x)
    z = proj[..., :d_in]
    xc = proj[..., d_in:2 * d_in]
    bmat = proj[..., 2 * d_in:2 * d_in + d_state]
    cmat = proj[..., 2 * d_in + d_state:2 * d_in + 2 * d_state]
    dt_pre = proj[..., 2 * d_in + 2 * d_state:]
    return z, xc, bmat, cmat, dt_pre


def mamba2_apply(p, x, *, heads: int, d_state: int, expand: int,
                 conv: int, ctx, chunk: int = 128,
                 return_state: bool = False):
    b, n, d = x.shape
    d_in = d * expand
    hd = d_in // heads
    z, xc, bmat, cmat, dt_pre = _mamba_proj_split(p, x, d_in, d_state)

    # causal depthwise conv, halo from the previous shard via the context
    tail = ctx.prev_tail(xc, conv - 1)
    xc_pad = jnp.concatenate([tail, xc], axis=1)
    conv_tail = (ctx.last_shard(xc_pad[:, -(conv - 1):])  # decode cache
                 if return_state else None)
    kern = p["conv"].astype(xc.dtype)
    xc = sum(xc_pad[:, i:i + n] * kern[i] for i in range(conv))
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + p["dt_bias"])            # (B,N,H)
    log_f = -dt * jnp.exp(p["a_log"])               # <= 0
    v = xc.reshape(b, n, heads, hd)
    k = jnp.repeat(bmat[:, :, None, :], heads, axis=2)   # shared B across heads
    q = jnp.repeat(cmat[:, :, None, :], heads, axis=2)
    y = chunked_linear_attention(q, k, v, log_f, dt,
                                 chunk=chunk, ctx=ctx, normalize=False,
                                 return_state=return_state)
    if return_state:
        y, state = y
    y = y + v * p["d_skip"].astype(v.dtype)[None, None, :, None]
    y = y.reshape(b, n, d_in) * jax.nn.silu(z)
    y = norm(p["ynorm"], y)
    out = dense(p["out"], y)
    return (out, {"s": state, "tail": conv_tail}) if return_state else out


def mamba2_decode(p, x, cache, *, heads: int, d_state: int, expand: int,
                  conv: int):
    """x (B,1,D), cache {'s': (B,H,dk,dv) f32, 'tail': (B,conv-1,d_in)}."""
    b, _, d = x.shape
    d_in = d * expand
    hd = d_in // heads
    z, xc, bmat, cmat, dt_pre = _mamba_proj_split(p, x[:, 0], d_in, d_state)

    window = jnp.concatenate([cache["tail"], xc[:, None]], axis=1)  # (B,conv,d_in)
    kern = p["conv"].astype(xc.dtype)
    xc = jnp.einsum("bcd,cd->bd", window, kern)
    xc = jax.nn.silu(xc)
    new_tail = window[:, 1:]

    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    f = jnp.exp(-dt * jnp.exp(p["a_log"]))
    v = xc.reshape(b, heads, hd)
    k = bmat                                           # (B, d_state), shared
    q = cmat
    kv = jnp.einsum("bd,bhv->bhdv", k, v) * dt[..., None, None].astype(v.dtype)
    s = f[..., None, None].astype(cache["s"].dtype) * cache["s"] + kv
    y = jnp.einsum("bd,bhdv->bhv", q, s.astype(q.dtype))
    y = y + v * p["d_skip"].astype(v.dtype)[None, :, None]
    y = y.reshape(b, d_in) * jax.nn.silu(z)
    y = norm(p["ynorm"], y)
    return dense(p["out"], y)[:, None], {"s": s, "tail": new_tail}

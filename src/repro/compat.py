"""Version shims.  The repo targets the modern ``jax.shard_map`` entry
point (jax >= 0.6, ``check_vma=``); on the 0.4.x line shard_map lives in
``jax.experimental.shard_map`` and the flag is spelled ``check_rep=``.
Route every shard_map through here so the runtime runs on both."""
from __future__ import annotations

import jax

try:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:                                    # pragma: no cover
    _legacy_shard_map = None


def axis_size(name):
    """``lax.axis_size`` (jax >= 0.6); on 0.4.x, ``psum(1, name)``
    constant-folds to the same static size inside a shard_map body."""
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma)

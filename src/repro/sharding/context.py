"""ShardedPrismContext — the PRISM protocol under ``shard_map``.

Runs inside a shard_map body whose activations are sharded
(batch over pod×data, sequence over ``model``).  The per-block exchange is:

  * PRISM:   ``lax.all_gather`` of the (B, L, D) segment means over
             ``model`` — (P-1)·L·D elements of useful payload per device
             per block (paper §IV-C);
  * Voltage: ``lax.all_gather`` of the full (B, N/P, D) partition —
             (P-1)·N·D/P elements (baseline [20]);
  * window:  ring ``ppermute`` halo of the last W tokens (gemma3 local
             layers need no Segment Means — DESIGN.md §6);
  * SSM:     constant-size state handoff via all_gather of (logA, U)
             chunk summaries;
  * MoE:     expert-parallel double ``all_to_all``;
  * sLSTM:   full-sequence gather (PRISM-inapplicable, DESIGN.md §6).

Own-partition segment means are *included* in the gathered tensor (static
shapes) but neutralized with g=0 — mathematically identical to the paper's
concat-of-others (Eq. 6) because a zero repeat count contributes nothing
to the scaling-aware softmax.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size

from ..core.segment_means import segment_means, segment_sizes, segment_bounds
from ..core.protocol import PrismConfig
from ..models.context import SeqContext, AugmentedKV
from ..models.layers import AttnSpec


class ShardedPrismContext(SeqContext):
    def __init__(self, cfg: PrismConfig, *, axis: str = "model",
                 n_shards: int, seq_shards: tuple = (),
                 prefix_len: int = 0, global_start: int = 0):
        """``axis``: mesh axis carrying PRISM's P (= ``n_shards``).
        ``seq_shards``: extra mesh axes the sequence is sharded over
        *in addition* to ``axis`` (long_500k shards sequence over
        data×model).  The combined shard count is P for the protocol."""
        # bind Eq. 16's P to the actual shard count: L = N/(CR·P) must see
        # the mesh's sequence parallelism, not the caller's placeholder P
        self.cfg = cfg.with_(P=n_shards) if cfg.P != n_shards else cfg
        self.axis = axis
        self.seq_axes = tuple(seq_shards) + (axis,)
        self.P = n_shards
        self.prefix_len = prefix_len
        self.global_start = global_start

    # -- helpers -----------------------------------------------------------

    def _index(self):
        idx = lax.axis_index(self.seq_axes[0])
        for a in self.seq_axes[1:]:
            idx = idx * axis_size(a) + lax.axis_index(a)
        return idx

    def _gather(self, x):
        """all_gather over the (combined) sequence axes -> leading shard dim."""
        g = lax.all_gather(x, self.seq_axes[-1], axis=0, tiled=False)
        for a in reversed(self.seq_axes[:-1]):
            g = lax.all_gather(g, a, axis=0, tiled=True)
        return g                                   # (P, ...)

    # -- attention ---------------------------------------------------------

    def augment(self, x, spec: AttnSpec):
        b, n_loc, d = x.shape
        p = self.P
        p_idx = self._index()
        start = self.global_start + p_idx * n_loc
        row_pos = start + jnp.arange(n_loc)

        if spec.window is not None:
            return self._augment_window(x, spec, n_loc, start, row_pos)
        if self.cfg.mode == "voltage":
            return self._augment_voltage(x, spec, n_loc, row_pos)
        return self._augment_prism(x, spec, n_loc, p_idx, start, row_pos)

    def _augment_voltage(self, x, spec, n_loc, row_pos):
        b = x.shape[0]
        xg = self._gather(x)                       # (P, B, n_loc, D)
        n = self.P * n_loc
        x_hat = jnp.moveaxis(xg, 0, 1).reshape(b, n, x.shape[-1])
        col = jnp.arange(n) + self.global_start
        vis = self._vis(row_pos, col, col, spec)
        return x, AugmentedKV(x_hat, None, vis, row_pos, col,
                              col_lo=col, col_hi=col)

    def _augment_prism(self, x, spec, n_loc, p_idx, start, row_pos):
        b, _, d = x.shape
        cfg = self.cfg
        n_global = self.P * n_loc
        L = cfg.landmarks(n_global)
        z = segment_means(x, L)                    # (B, L, D)
        zg = self._gather(z)                       # (P, B, L, D)
        z_all = jnp.moveaxis(zg, 0, 1).reshape(b, self.P * L, d)
        x_hat = jnp.concatenate([x, z_all], axis=1)    # (B, n_loc + P·L, D)

        sizes = jnp.asarray(segment_sizes(n_loc, L), jnp.float32)
        lo0, hi0 = segment_bounds(n_loc, L)        # per-partition template
        shard_of = jnp.repeat(jnp.arange(self.P), L)
        offs = self.global_start + jnp.repeat(jnp.arange(self.P) * n_loc, L)
        z_lo = jnp.tile(jnp.asarray(lo0), self.P) + offs
        z_hi = jnp.tile(jnp.asarray(hi0), self.P) + offs
        # own-partition means: g = 0 (exact local columns already present)
        z_g = jnp.where(shard_of == p_idx, 0.0, jnp.tile(sizes, self.P))

        col_lo = jnp.concatenate([row_pos, z_lo])
        col_hi = jnp.concatenate([row_pos, z_hi])
        g = jnp.concatenate([jnp.ones((n_loc,), jnp.float32), z_g])
        col_pos = jnp.concatenate(
            [row_pos.astype(jnp.float32), (z_lo + z_hi) / 2.0])
        vis = self._vis(row_pos, col_lo, col_hi, spec)
        vis = vis & (g > 0)[None, :]
        # g = 0 columns need no mask entry for the kernel: log g = -inf
        # already zeroes them, so (col_lo, col_hi) alone reproduce vis
        return x, AugmentedKV(x_hat, g, vis, row_pos, col_pos,
                              col_lo=col_lo, col_hi=col_hi)

    def _augment_window(self, x, spec, n_loc, start, row_pos):
        """Ring halo: gather the previous ceil(W / n_loc) shards' tokens."""
        b, _, d = x.shape
        w = spec.window
        hops = min(self.P - 1, -(-w // n_loc))     # ceil
        tails = []
        for h in range(hops, 0, -1):
            perm = [(s, s + h) for s in range(self.P - h)]
            tails.append(self._ring_permute(x, perm))
        x_hat = jnp.concatenate(tails + [x], axis=1)
        m = (hops + 1) * n_loc
        col = start - hops * n_loc + jnp.arange(m)
        vis = self._vis(row_pos, col, col, spec)
        vis = vis & (col >= 0)[None, :]            # halo beyond seq start
        return x, AugmentedKV(x_hat, None, vis, row_pos,
                              jnp.maximum(col, 0))

    def _ring_permute(self, x, perm):
        """ppermute over the combined sequence axes (flattened index)."""
        if len(self.seq_axes) == 1:
            return lax.ppermute(x, self.seq_axes[0], perm)
        # combined-axis permute: gather then select is wasteful; for the
        # multi-axis case (long_500k) halo hops stay within the minor axis
        # except at boundaries — implement as permute on the minor axis and
        # a corrective permute on the major axis for the wrap column.
        minor = self.seq_axes[-1]
        major = self.seq_axes[0]
        pm = axis_size(minor)
        # shift-by-h on the flattened index decomposes into minor shift and
        # major carry; for h < pm (always true here) one carry at most.
        h = perm[0][1] - perm[0][0]
        shifted = lax.ppermute(
            x, minor, [(s, s + h) for s in range(pm - h)])
        carried = lax.ppermute(
            x, minor, [(pm - h + i, i) for i in range(h)])
        carried = lax.ppermute(
            carried, major,
            [(s, s + 1) for s in range(axis_size(major) - 1)])
        idx_minor = lax.axis_index(minor)
        return jnp.where(idx_minor < h, carried, shifted)

    def _vis(self, row_pos, col_lo, col_hi, spec):
        r = row_pos[:, None]
        if spec.causal:
            vis = col_hi[None, :] <= r
            if self.prefix_len > 0:
                vis = vis | (col_hi[None, :] < self.prefix_len)
        else:
            vis = jnp.ones((row_pos.shape[0], col_lo.shape[0]), bool)
        if spec.window is not None:
            vis = vis & (col_lo[None, :] > r - spec.window)
        return vis

    # -- SSM ----------------------------------------------------------------

    def state_handoff(self, log_a_tot, u_tot):
        la = self._gather(log_a_tot)               # (P, B, H)
        u = self._gather(u_tot)                    # (P, B, H, dk, dv)
        p_idx = self._index()

        def step(carry, xs):
            la_q, u_q = xs
            new = jnp.exp(la_q)[..., None, None] * carry + u_q
            return new, carry                      # emit EXCLUSIVE prefix
        _, prefixes = lax.scan(step, jnp.zeros_like(u[0]), (la, u))
        return jnp.take(prefixes, p_idx, axis=0)   # (B, H, dk, dv)

    def gather_sequence(self, x):
        g = self._gather(x)                        # (P, B, n_loc, D)
        return jnp.moveaxis(g, 0, 1).reshape(
            x.shape[0], -1, x.shape[-1])

    def take_local(self, y_full):
        n_loc = y_full.shape[1] // self.P
        start = self._index() * n_loc
        return lax.dynamic_slice_in_dim(y_full, start, n_loc, axis=1)

    def prev_tail(self, x, size: int):
        tail = x[:, -size:]
        perm = [(s, s + 1) for s in range(self.P - 1)]
        return self._ring_permute_simple(tail, perm)

    def last_shard(self, x):
        """Broadcast the final shard's value to all shards (psum of a
        one-hot-masked value — one small collective per decode-cache leaf)."""
        sel = (self._index() == self.P - 1)
        masked = jnp.where(sel, x.astype(jnp.float32), 0.0)
        for a in self.seq_axes:
            masked = lax.psum(masked, a)
        return masked.astype(x.dtype)

    def _ring_permute_simple(self, x, perm):
        if len(self.seq_axes) == 1:
            return lax.ppermute(x, self.seq_axes[0], perm)
        return self._ring_permute(x, perm)

    # -- MoE -----------------------------------------------------------------

    def expert_exchange(self, buf):
        """(E, cap, D) -> (E_local, P·cap, D) via tiled all_to_all."""
        ax = self.axis
        p = axis_size(ax)
        out = lax.all_to_all(buf, ax, split_axis=0, concat_axis=1, tiled=True)

        def undo(y):
            return lax.all_to_all(y, ax, split_axis=1, concat_axis=0,
                                  tiled=True)
        return out, undo

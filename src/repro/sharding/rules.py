"""Parameter sharding rules (path-based, over the production mesh).

Layout summary (DESIGN.md §4):

  * expert weights   — expert dim over ``model`` (+ FSDP over ``data`` on
                       the widest remaining dim): expert parallelism.
  * embed / lm_head  — vocab dim over ``model`` (vocab-parallel input
                       lookup and loss; avoids gathering a 256k×d table).
  * other ≥2-D       — FSDP over ``data`` on the first divisible dim;
                       gathered per-layer on use inside the shard_map body
                       (the all_gather's transpose reduce-scatters grads —
                       ZeRO-3 for free).
  * small / 1-D      — replicated.
  * ``pod``          — parameters replicated across pods (pure DP);
                       gradients psum over ``pod``.

Each rule also records which mesh axes the *gradient* must still be
psum-reduced over inside the body (axes whose sum is NOT already handled
by an all_gather transpose).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamRule:
    spec: P                      # partition spec over the mesh
    gather_dim: int | None       # dim all-gathered over 'data' in the body
    grad_reduce: tuple           # axes to psum gradients over
    kind: str                    # 'expert' | 'vocab' | 'fsdp' | 'replicated'


# threshold below which we don't bother sharding
_FSDP_MIN = 1 << 16


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def rule_for(path: str, shape: tuple, mesh_axes: dict,
             vocab_size: int) -> ParamRule:
    """Stacked scan layers ('scan/<j>/...') carry a leading depth dim:
    the rule is computed on the SLICED shape (the form the shard_map body
    sees inside lax.scan) and the stored spec gets a leading None.
    ``gather_dim`` refers to the sliced leaf."""
    if path.startswith("scan/"):
        inner = rule_for(path.split("/", 2)[2], shape[1:], mesh_axes,
                         vocab_size)
        return ParamRule(P(*((None,) + tuple(inner.spec))),
                         inner.gather_dim, inner.grad_reduce, inner.kind)
    return _rule_for_flat(path, shape, mesh_axes, vocab_size)


def _rule_for_flat(path: str, shape: tuple, mesh_axes: dict,
                   vocab_size: int) -> ParamRule:
    data = mesh_axes.get("data", 1)
    model = mesh_axes.get("model", 1)
    has_pod = "pod" in mesh_axes
    pod = ("pod",) if has_pod else ()

    # --- experts: (E, ...) with E % model == 0 ---
    if "/experts/" in path or path.endswith("experts"):
        spec = [None] * len(shape)
        spec[0] = "model"
        gdim = None
        if len(shape) >= 2:
            widest = int(np.argmax(shape[1:])) + 1
            if shape[widest] % data == 0 and np.prod(shape) >= _FSDP_MIN:
                spec[widest] = "data"
                gdim = widest
        return ParamRule(P(*spec), gdim, pod, "expert")

    # --- vocab-dimension params: embed table / untied lm_head ---
    if path == "embed/table" or "lm_head" in path:
        cands = [i for i, s in enumerate(shape) if s == vocab_size]
        # embed table is (vocab, d); lm_head w is (d, vocab) — when
        # d == vocab both dims match, so pick by layout.
        vdim = (cands[-1] if "lm_head" in path else cands[0]) if cands else 0
        if shape[vdim] % model == 0:
            spec = [None] * len(shape)
            spec[vdim] = "model"
            return ParamRule(P(*spec), None, pod + ("data",), "vocab")
        return ParamRule(P(), None, pod + ("data", "model"), "replicated")

    # --- generic FSDP: 2D (data × model) when two dims divide ---
    # Sharding a second dim over 'model' turns the gradient all-reduce
    # over 'model' into the all-gather transpose's reduce-scatter (half
    # the link bytes) and cuts per-device param+optimizer memory by
    # another model-fold (§Perf H2).
    if len(shape) >= 2 and int(np.prod(shape)) >= _FSDP_MIN:
        dim_d = next((d for d, s in enumerate(shape) if s % data == 0),
                     None)
        if dim_d is not None:
            spec = [None] * len(shape)
            spec[dim_d] = "data"
            dim_m = next((d for d, s in enumerate(shape)
                          if d != dim_d and s % model == 0), None)
            if dim_m is not None:
                spec[dim_m] = "model"
                return ParamRule(P(*spec), (dim_d, dim_m), pod, "fsdp2d")
            return ParamRule(P(*spec), dim_d, pod + ("model",), "fsdp")

    return ParamRule(P(), None, pod + ("data", "model"), "replicated")


def param_specs(params, mesh, vocab_size: int):
    """Pytree of ParamRule matching ``params`` (arrays OR ShapeDtypeStructs
    — the dry-run builds rules from eval_shape trees without allocating)."""
    mesh_ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    rules = [rule_for(_path_str(p), getattr(leaf, "shape", None)
                      or np.shape(leaf), mesh_ax, vocab_size)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, rules)


def spec_tree(rules):
    return jax.tree.map(lambda r: r.spec, rules,
                        is_leaf=lambda x: isinstance(x, ParamRule))


def gather_tree(local_params, rules):
    """FSDP all-gather inside a shard_map body (per layer): over 'data'
    (1D) or 'data'+'model' (2D, tuple gather_dim)."""
    def g(x, r):
        if r.gather_dim is None:
            return x
        if isinstance(r.gather_dim, tuple):
            dd, dm = r.gather_dim
            x = lax.all_gather(x, "data", axis=dd, tiled=True)
            return lax.all_gather(x, "model", axis=dm, tiled=True)
        return lax.all_gather(x, "data", axis=r.gather_dim, tiled=True)
    return jax.tree.map(g, local_params, rules,
                        is_leaf=lambda x: isinstance(x, ParamRule))


def decode_rule_for(path: str, shape: tuple, mesh_axes: dict,
                    vocab_size: int, *, attn_tp: bool, ffn_tp: bool
                    ) -> ParamRule:
    """Decode-time tensor-parallel layout (§Perf hillclimb H1).

    FSDP's gather-per-layer re-moves the full parameter set across the
    mesh for every decoded token — the collective-bound decode baseline.
    For decode we instead keep weights SHARDED over ``model`` Megatron
    style and psum small activations:

      wq, mlp up/gate   column-parallel  P(None, 'model')
      wo, mlp down      row-parallel     P('model', None)
      wk/wv, norms, ssm cells, embeddings' friends: replicated (small)
      experts           unchanged (expert-parallel over 'model')
      embed/lm_head     vocab-parallel over 'model' (unchanged)

    kind='tp_col'/'tp_row'/'replicated'; gather_dim is always None — the
    decode body never all-gathers parameters.
    """
    model = mesh_axes.get("model", 1)
    data = mesh_axes.get("data", 1)
    if path.startswith("scan/"):
        inner = decode_rule_for(path.split("/", 2)[2], shape[1:], mesh_axes,
                                vocab_size, attn_tp=attn_tp, ffn_tp=ffn_tp)
        return ParamRule(P(*((None,) + tuple(inner.spec))), None,
                         inner.grad_reduce, inner.kind)
    if "/experts/" in path or path.endswith("experts"):
        # expert-parallel over 'model' + expert-TP over 'data': the
        # per-expert d_ff dim is column-(up/gate)/row-(down)-split so no
        # per-token gather is ever needed (arctic: 58 GB/chip replicated
        # otherwise); moe_apply psums the down partials over 'data'.
        spec = [None] * len(shape)
        spec[0] = "model"
        kind = "expert"
        if len(shape) == 3:
            if path.endswith("down/w") and shape[1] % data == 0:
                spec[1] = "data"
                kind = "expert_tp_row"
            elif shape[2] % data == 0:          # up/gate
                spec[2] = "data"
                kind = "expert_tp_col"
        return ParamRule(P(*spec), None, (), kind)
    if path == "embed/table" or "lm_head" in path:
        return _rule_for_flat(path, shape, mesh_axes, vocab_size)
    if attn_tp and len(shape) == 2 and path.endswith("wq/w") \
            and shape[1] % model == 0:
        return ParamRule(P(None, "model"), None, (), "tp_col")
    if attn_tp and len(shape) == 2 and path.endswith("wo/w") \
            and shape[0] % model == 0:
        return ParamRule(P("model", None), None, (), "tp_row")
    is_mlp = ("mlp/" in path) and ("cell/" not in path)   # incl. dense_mlp
    if ffn_tp and is_mlp and len(shape) == 2:
        if (path.endswith("up/w") or path.endswith("gate/w")) \
                and shape[1] % model == 0:
            return ParamRule(P(None, "model"), None, (), "tp_col")
        if path.endswith("down/w") and shape[0] % model == 0:
            return ParamRule(P("model", None), None, (), "tp_row")
    return ParamRule(P(), None, (), "replicated")


def decode_param_specs(params, mesh, vocab_size: int, cfg):
    """Pytree of decode-TP ParamRules (see decode_rule_for)."""
    mesh_ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = mesh_ax.get("model", 1)
    attn_tp = (cfg.n_heads % model == 0 and not cfg.attn_bias
               and (cfg.n_heads * cfg.hd) % model == 0)
    ffn_tp = (cfg.d_ff % model == 0 and not cfg.attn_bias
              if cfg.d_ff else False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    rules = [decode_rule_for(
        _path_str(p), getattr(leaf, "shape", None) or np.shape(leaf),
        mesh_ax, vocab_size, attn_tp=attn_tp, ffn_tp=ffn_tp)
        for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, rules)


class GradReduce:
    """psum gradients over the axes each rule still needs."""

    @staticmethod
    def apply(grads, rules, mesh):
        names = set(mesh.axis_names)

        def red(g, r):
            axes = tuple(a for a in r.grad_reduce if a in names)
            return lax.psum(g, axes) if axes else g
        return jax.tree.map(red, grads, rules,
                            is_leaf=lambda x: isinstance(x, ParamRule))


def opt_state_specs(rules, params, mesh):
    """m/v mirror the param spec, additionally sharded over spare axes on
    the widest unsharded dim (ZeRO-ish optimizer-state sharding)."""
    mesh_ax = dict(zip(mesh.axis_names, mesh.devices.shape))

    def s(r, p):
        shape = getattr(p, "shape", None)
        if shape is None:
            shape = np.shape(p)
        used = set(a for a in r.spec if a)
        spare = [a for a in ("model", "pod") if a in mesh_ax and a not in used]
        spec = list(r.spec) + [None] * (len(shape) - len(r.spec))
        for dim, sz in enumerate(shape):
            if spec[dim] is None and spare and sz % mesh_ax[spare[0]] == 0 \
                    and int(np.prod(shape)) >= _FSDP_MIN:
                spec[dim] = spare.pop(0)
                break
        return P(*spec)
    return jax.tree.map(s, rules, params,
                        is_leaf=lambda x: isinstance(x, ParamRule))

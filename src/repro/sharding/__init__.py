from .context import ShardedPrismContext  # noqa: F401
from .rules import param_specs, GradReduce  # noqa: F401

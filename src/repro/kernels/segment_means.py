"""Pallas TPU kernel: Segment Means reduction (paper Alg. 2).

Memory-bound: one pass (N_p, D) → (L, D).  Exists to fuse the per-block
compression with the residual-stream write — on TPU the block output is
already streaming through VMEM, so computing the means there saves a full
HBM round-trip over a separate jnp.mean (see EXPERIMENTS.md §Perf).

Grid (L, D/blk_d): each program mean-reduces one (segment × feature-block)
tile.  Even segments only (N_p % L == 0) — the ragged tail uses the jnp
path (`repro.core.segment_means`), which is also the kernel's oracle.
``interpret=None`` auto-detects the platform (``kernels.dispatch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret


def _kernel(x_ref, o_ref, *, seg: int):
    o_ref[...] = jnp.mean(
        x_ref[...].astype(jnp.float32), axis=0, keepdims=True
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("L", "block_d", "interpret"))
def segment_means_op(x, *, L: int, block_d: int = 512,
                     interpret: bool | None = None):
    """x (B, N_p, D) -> (B, L, D); requires N_p % L == 0."""
    interpret = default_interpret(interpret)
    b, n, d = x.shape
    assert n % L == 0, "kernel path needs even segments; use jnp fallback"
    seg = n // L
    block_d = min(block_d, d)
    if d % block_d:
        # largest divisor of d keeps the feature-block grid (768 with
        # the default 512 -> 384); degenerate divisors (prime-ish d)
        # fall back to one full-width tile
        div = next(x for x in range(block_d, 0, -1) if d % x == 0)
        block_d = div if div >= 128 else d

    def run(x2):          # (N_p, D) -> (L, D)
        return pl.pallas_call(
            functools.partial(_kernel, seg=seg),
            grid=(L, d // block_d),
            in_specs=[pl.BlockSpec((seg, block_d), lambda l, j: (l, j))],
            out_specs=pl.BlockSpec((1, block_d), lambda l, j: (l, j)),
            out_shape=jax.ShapeDtypeStruct((L, d), x2.dtype),
            interpret=interpret,
        )(x2)

    return jax.vmap(run)(x)

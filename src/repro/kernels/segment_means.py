"""Pallas TPU kernel: Segment Means reduction (paper Alg. 2).

Memory-bound: one pass (N_p, D) → (L, D).  Exists to fuse the per-block
compression with the residual-stream write — on TPU the block output is
already streaming through VMEM, so computing the means there saves a full
HBM round-trip over a separate jnp.mean (see EXPERIMENTS.md §Perf).

Grid (L, D/blk_d): each program mean-reduces one (segment × feature-block)
tile.  Ragged partitions (N_p % L != 0) follow the paper's Eq. 8 split —
the first L-1 even segments stream through the kernel, the oversized
last segment is mean-reduced in jnp with one static slice (matching
`repro.core.segment_means`, which is also the kernel's oracle).
``interpret=None`` auto-detects the platform (``kernels.dispatch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dispatch import default_interpret


def _kernel(x_ref, o_ref, *, seg: int):
    o_ref[...] = jnp.mean(
        x_ref[...].astype(jnp.float32), axis=0, keepdims=True
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("L", "block_d", "interpret"))
def segment_means_op(x, *, L: int, block_d: int = 512,
                     interpret: bool | None = None):
    """x (B, N_p, D) -> (B, L, D) segment means, any 1 <= L <= N_p."""
    interpret = default_interpret(interpret)
    b, n, d = x.shape
    assert 1 <= L <= n, (L, n)
    if n % L:
        # Eq. 8 ragged split: L-1 even segments + one oversized tail.
        s = n // L
        tail = jnp.mean(x[:, s * (L - 1):].astype(jnp.float32), axis=1,
                        keepdims=True).astype(x.dtype)
        if L == 1:
            return tail
        head = segment_means_op(x[:, : s * (L - 1)], L=L - 1,
                                block_d=block_d, interpret=interpret)
        return jnp.concatenate([head, tail], axis=1)
    seg = n // L
    block_d = min(block_d, d)
    if d % block_d:
        # largest divisor of d keeps the feature-block grid (768 with
        # the default 512 -> 384); degenerate divisors (prime-ish d)
        # fall back to one full-width tile
        div = next(x for x in range(block_d, 0, -1) if d % x == 0)
        block_d = div if div >= 128 else d

    def run(x2):          # (N_p, D) -> (L, D)
        return pl.pallas_call(
            functools.partial(_kernel, seg=seg),
            grid=(L, d // block_d),
            in_specs=[pl.BlockSpec((seg, block_d), lambda l, j: (l, j))],
            out_specs=pl.BlockSpec((1, block_d), lambda l, j: (l, j)),
            out_shape=jax.ShapeDtypeStruct((L, d), x2.dtype),
            interpret=interpret,
        )(x2)

    return jax.vmap(run)(x)

"""Pallas TPU kernel: fused single-token (flash-decode) GQA attention
over the local KV-cache shard, with the PRISM means columns folded in.

This is the serving hot path: the continuous-batching engine calls it
once per layer per generated token.  Design points:

  * **Partial stats out, not outputs.**  The kernel emits the running
    softmax statistics ``(m, l, acc)`` — O(B·Hq·hd), independent of the
    cache capacity — so the existing ``pmax``/``psum`` cross-shard
    combine in ``runtime/serve.py`` is untouched and the exact
    distributed flash-decode stays *exact*.
  * **Per-row validity.**  Continuous-batching slots decode at
    independent depths; ``valid (B, M)`` carries each row's column
    visibility (idle slots: all-False).  A row with no valid column
    anywhere yields ``l = 0`` (its exp terms are re-zeroed), which the
    combine maps to a finite zero output.
  * **Prism means in-kernel.**  In ``prism`` decode mode the cached
    Segment-Means K/V ride along as extra K-blocks with a ``+log g``
    column bias (Eq. 14 as an additive logit term) — the per-step
    ``jnp.concatenate`` of the cache shard with the means cache (a
    cache-capacity-sized HBM allocation per layer per token) disappears.
  * **GQA in the grid.**  Grid (B, Hkv, K-blocks): each program attends
    the ``grp = Hq/Hkv`` query heads of one KV head against one K/V
    tile, so grouped heads share tiles without materializing the repeat.

``decode_stats_reference`` is the pure-jnp oracle — the same two-pass
(local columns, then means columns) stat merge, also concatenate-free,
and what ``backend='jnp'`` serves with on CPU/GPU.  See EXPERIMENTS.md
§Perf for the measured win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.attention import _gqa_logits, _gqa_output
from .ops import _pad_to
from .prism_attention import NEG
from .dispatch import default_interpret


# --------------------------------------------------------------------------
# jnp oracle: two-pass partial stats + merge (no concatenate)
# --------------------------------------------------------------------------

def partial_softmax_stats(q, k, v, bias, scale):
    """Softmax partial stats over one column set.  q (B,1,Hq,hd);
    k,v (B,M,Hkv,hd); bias (B,M) additive logits (NEG = dead column).
    Returns m, l: (B,Hq,1,1) f32 and acc: (B,1,Hq,hd) f32.  Rows with
    every column dead come back as (m=NEG, l=0, acc=0)."""
    s = _gqa_logits(q, k, scale).astype(jnp.float32)      # (B,Hq,1,M)
    s = s + bias[:, None, None, :].astype(jnp.float32)
    m_p = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m_p)
    p = jnp.where(s > NEG / 2, p, 0.0)                    # all-dead -> l=0
    l_p = jnp.sum(p, axis=-1, keepdims=True)
    acc_p = _gqa_output(p.astype(v.dtype), v).astype(jnp.float32)
    return m_p, l_p, acc_p


def merge_stats(a, b):
    """Combine two partial-stat triples over disjoint column sets —
    the associative flash-softmax merge (what lax.pmax/psum do across
    shards, here across the local/means passes of one shard).  Shape-
    generic over the query count: m, l (B,Hq,Nq,1), acc (B,Nq,Hq,hd)
    — the chunked-prefill pass merges Nq > 1 queries at once."""
    m_a, l_a, acc_a = a
    m_b, l_b, acc_b = b
    m = jnp.maximum(m_a, m_b)
    c_a = jnp.exp(m_a - m)
    c_b = jnp.exp(m_b - m)
    l = l_a * c_a + l_b * c_b
    acc = (acc_a * jnp.swapaxes(c_a[..., 0], 1, 2)[..., None]
           + acc_b * jnp.swapaxes(c_b[..., 0], 1, 2)[..., None])
    return m, l, acc


def chunk_softmax_stats(q, k, v, bias, scale):
    """Multi-query softmax partial stats with a *per-query* additive
    bias — the intra-chunk pass of chunked prefill (each chunk query
    sees a different causal prefix of the chunk's own columns).

    q (B,C,Hq,hd); k,v (B,M,Hkv,hd); bias (B,C,M) additive logits
    (NEG = dead column).  Returns m, l: (B,Hq,C,1) f32 and
    acc: (B,C,Hq,hd) f32 — merge_stats/``_combine_exact`` compatible."""
    s = _gqa_logits(q, k, scale).astype(jnp.float32)      # (B,Hq,C,M)
    s = s + bias[:, None].astype(jnp.float32)
    m_p = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m_p)
    p = jnp.where(s > NEG / 2, p, 0.0)                    # all-dead -> l=0
    l_p = jnp.sum(p, axis=-1, keepdims=True)
    acc_p = _gqa_output(p.astype(v.dtype), v).astype(jnp.float32)
    return m_p, l_p, acc_p


def decode_stats_reference(q, k, v, valid, log_gz=None, kz=None, vz=None,
                           *, scale):
    """jnp oracle for ``flash_decode_stats``: local columns masked by
    ``valid`` (g=1), then the optional means columns with their
    per-row ``log_gz`` bias, merged without ever concatenating K/V."""
    bias = jnp.where(valid, 0.0, NEG)
    stats = partial_softmax_stats(q, k, v, bias, scale)
    if kz is not None:
        stats = merge_stats(stats, partial_softmax_stats(
            q, kz.astype(k.dtype), vz.astype(v.dtype), log_gz, scale))
    return stats


# --------------------------------------------------------------------------
# Pallas kernel
# --------------------------------------------------------------------------

def _decode_kernel(valid_ref, q_ref, k_ref, v_ref,
                   *rest, scale, nk_loc, nk):
    """One (batch row, KV head) flash-decode pass.  K-blocks are the
    innermost grid dim: indices [0, nk_loc) stream the local cache
    shard, [nk_loc, nk) the means columns (when present)."""
    if nk > nk_loc:
        loggz_ref, kz_ref, vz_ref = rest[:3]
        m_out, l_out, acc_out, m_scr, l_scr, acc_scr = rest[3:]
    else:
        m_out, l_out, acc_out, m_scr, l_scr, acc_scr = rest
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]                                   # (grp, hd)

    def update(s, v):                                # s (grp, blk_k)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(s > NEG / 2, p, 0.0)           # dead cols -> l=0
        l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if nk_loc > 0:
        @pl.when(ki < nk_loc)
        def _local():
            k = k_ref[...]                           # (blk_k, hd)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            ok = valid_ref[...] != 0                 # (1, blk_k)
            update(jnp.where(ok, s, NEG), v_ref[...])

    if nk > nk_loc:
        @pl.when(ki >= nk_loc)
        def _means():
            kz = kz_ref[...]
            s = jax.lax.dot_general(
                q, kz, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale
            logg = loggz_ref[...].astype(jnp.float32)   # (1, blk_k)
            update(jnp.maximum(s + logg, NEG), vz_ref[...])

    @pl.when(ki == nk - 1)
    def _fin():
        m_out[...] = m_scr[...]
        l_out[...] = l_scr[...]
        acc_out[...] = acc_scr[...]


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode_stats(
    q,                # (B, 1, Hq, hd) — the single decode token per slot
    k,                # (B, M, Hkv, hd) local cache shard
    v,                # (B, M, Hkv, hd)
    valid,            # (B, M) bool — per-row column visibility
    log_gz=None,      # (B, m) f32 — per-row means-column log repeat
                      #   counts; NEG on dead columns (own shard / future)
    kz=None,          # (B, m, Hkv, hd) Segment-Means K cache
    vz=None,          # (B, m, Hkv, hd)
    *,
    scale: float,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """Fused flash-decode partial stats.  Returns (m, l, acc) with the
    ``flash_decode_combine`` shapes — m, l: (B,Hq,1,1) f32,
    acc: (B,1,Hq,hd) f32 — ready for the cross-shard pmax/psum combine
    (or, in prism mode, local normalization + owner select)."""
    interpret = default_interpret(interpret)
    b, nq, hq, hd = q.shape
    assert nq == 1, f"decode kernel is single-token (got Nq={nq})"
    _, m_loc, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    grp = hq // hkv
    block_k = min(block_k, max(8, 1 << (m_loc - 1).bit_length()))

    qk = q[:, 0].reshape(b, hkv, grp, hd)
    kt = _pad_to(k.swapaxes(1, 2), block_k, 2)       # (B,Hkv,M',hd)
    vt = _pad_to(v.swapaxes(1, 2), block_k, 2)
    validp = _pad_to(valid.astype(jnp.int32), block_k, 1)
    nk_loc = kt.shape[2] // block_k

    has_means = kz is not None
    if has_means:
        kzt = _pad_to(kz.astype(k.dtype).swapaxes(1, 2), block_k, 2)
        vzt = _pad_to(vz.astype(v.dtype).swapaxes(1, 2), block_k, 2)
        lgz = _pad_to(log_gz.astype(jnp.float32), block_k, 1, value=NEG)
        nk_means = kzt.shape[2] // block_k
    else:
        nk_means = 0
    nk = nk_loc + nk_means

    def loc(ki):
        return jnp.minimum(ki, nk_loc - 1)

    def mns(ki):
        return jnp.clip(ki - nk_loc, 0, max(nk_means - 1, 0))

    in_specs = [
        pl.BlockSpec((1, block_k), lambda bi, h, ki: (bi, loc(ki))),
        pl.BlockSpec((None, None, grp, hd), lambda bi, h, ki: (bi, h, 0, 0)),
        pl.BlockSpec((None, None, block_k, hd),
                     lambda bi, h, ki: (bi, h, loc(ki), 0)),
        pl.BlockSpec((None, None, block_k, hd),
                     lambda bi, h, ki: (bi, h, loc(ki), 0)),
    ]
    args = [validp, qk, kt, vt]
    if has_means:
        in_specs += [
            pl.BlockSpec((1, block_k), lambda bi, h, ki: (bi, mns(ki))),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda bi, h, ki: (bi, h, mns(ki), 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda bi, h, ki: (bi, h, mns(ki), 0)),
        ]
        args += [lgz, kzt, vzt]

    stat_spec = pl.BlockSpec((None, None, grp, 1),
                             lambda bi, h, ki: (bi, h, 0, 0))
    acc_spec = pl.BlockSpec((None, None, grp, hd),
                            lambda bi, h, ki: (bi, h, 0, 0))
    m_o, l_o, acc_o = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale,
                          nk_loc=nk_loc, nk=nk),
        grid=(b, hkv, nk),
        in_specs=in_specs,
        out_specs=[stat_spec, stat_spec, acc_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, grp, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, grp, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, grp, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((grp, 1), jnp.float32),       # running max m
            pltpu.VMEM((grp, 1), jnp.float32),       # normalizer l
            pltpu.VMEM((grp, hd), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(*args)

    m_p = m_o.reshape(b, hq)[:, :, None, None]
    l_p = l_o.reshape(b, hq)[:, :, None, None]
    acc_p = acc_o.reshape(b, hq, hd)[:, None]        # (B,1,Hq,hd)
    return m_p, l_p, acc_p

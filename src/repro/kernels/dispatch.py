"""Kernel-backend dispatch: one switch for every fused-kernel call site.

The repo carries two implementations of each hot op — a Pallas TPU
kernel and a pure-jnp oracle.  Which one runs is a *deployment* choice,
not something each call site should re-derive, so this module owns the
single rule:

    backend = "auto"    -> "pallas" on TPU, "jnp" everywhere else
    backend = "pallas"  -> the kernel, compiled on TPU, interpret-mode
                           (Pallas's Python emulator) elsewhere — the
                           validation configuration the kernel tests use
    backend = "jnp"     -> the jnp oracle, always

Consumed by ``kernels.ops.prism_attention_op`` (prefill),
``kernels.segment_means.segment_means_op``,
``kernels.decode_attention.flash_decode_stats`` (the serving hot path),
and plumbed through ``ServeHParams.backend`` / ``launch.serve
--backend``.  ``PRISM_KERNEL_BACKEND`` overrides the default for code
paths that don't thread the switch explicitly.
"""
from __future__ import annotations

import os

import jax

BACKENDS = ("auto", "pallas", "jnp")


def platform() -> str:
    """The default JAX backend platform ('tpu' | 'gpu' | 'cpu')."""
    return jax.default_backend()


def resolve_backend(backend: str | None = None) -> str:
    """'auto' (or None) -> the PRISM_KERNEL_BACKEND env override if set,
    else 'pallas' on TPU / 'jnp' elsewhere; explicit 'pallas'/'jnp'
    always wins over the env.  Raises on anything outside BACKENDS."""
    if backend is None:
        backend = "auto"
    if backend == "auto":
        backend = os.environ.get("PRISM_KERNEL_BACKEND", "auto")
    if backend not in BACKENDS:
        raise ValueError(
            f"backend {backend!r} not in {BACKENDS}")
    if backend == "auto":
        return "pallas" if platform() == "tpu" else "jnp"
    return backend


def use_pallas(backend: str | None = None) -> bool:
    return resolve_backend(backend) == "pallas"


def pallas_interpret() -> bool:
    """Whether a Pallas call must run in interpret mode: anywhere but a
    real TPU.  Forcing backend='pallas' on CPU therefore runs the kernel
    through the Pallas interpreter — slow, but the exact kernel code the
    TPU compiles, which is what the oracle tests exercise."""
    return platform() != "tpu"


def default_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` kwarg: None means platform auto-detect
    (the old hard-coded ``interpret=True`` defaults silently ran the
    emulator on TPU — slow-by-default; this is the fix)."""
    return pallas_interpret() if interpret is None else bool(interpret)

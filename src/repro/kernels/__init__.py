"""Pallas TPU kernels + their jnp oracles for the repo's compute
hot-spots, behind one backend switch (``dispatch.py``):

  * ``prism_attention.py`` / ``ops.py`` — scaling-aware flash attention
    (prefill path); ``ref.py`` is the dense oracle.
  * ``decode_attention.py`` — fused single-token flash-decode partial
    stats (the serving hot path), plus the concatenate-free two-pass
    jnp reference.
  * ``segment_means.py`` — fused Alg. 2 reduction.

Every kernel validates against its oracle in interpret mode
(tests/test_kernels.py, tests/test_decode_attention.py); ``interpret``
defaults to platform auto-detection, so the same call sites compile on
TPU and emulate elsewhere.
"""
from .decode_attention import (decode_stats_reference, flash_decode_stats,
                               merge_stats, partial_softmax_stats)
from .dispatch import (BACKENDS, default_interpret, pallas_interpret,
                       resolve_backend, use_pallas)
from .ops import prism_attention_op
from .segment_means import segment_means_op

__all__ = [
    "BACKENDS", "decode_stats_reference", "default_interpret",
    "flash_decode_stats", "merge_stats", "pallas_interpret",
    "partial_softmax_stats", "prism_attention_op", "resolve_backend",
    "segment_means_op", "use_pallas",
]

"""Jitted public wrapper around the PRISM flash-attention Pallas kernel.

Handles layout (B,N,H,hd ↔ B,H,N,hd), block-multiple padding (padded
columns get g=0 ⇒ log g = -1e30 ⇒ zero attention weight), and the
interpret-mode switch (``interpret=None`` auto-detects: compiled on
TPU, the Pallas interpreter for CPU validation — see
``kernels.dispatch``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.attention import log_repeats
from .dispatch import default_interpret
from .prism_attention import prism_flash_attention, NEG


def _pad_to(x, mult, axis, value=0.0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "prefix_len", "window", "scale",
                     "block_q", "block_k", "interpret"))
def prism_attention_op(
    q,            # (B, Nq, Hq, hd)
    k,            # (B, M, Hkv, hd)
    v,            # (B, M, Hkv, hd)
    g,            # (M,) float32 repeat counts (0 = masked/padding)
    col_lo,       # (M,) int32
    col_hi,       # (M,) int32
    row_pos,      # (Nq,) int32
    *,
    causal: bool = True,
    prefix_len: int = 0,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
):
    interpret = default_interpret(interpret)
    b, nq, hq, hd = q.shape
    m = k.shape[1]
    scale = float(hd ** -0.5) if scale is None else scale
    block_q = min(block_q, max(8, 1 << (nq - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (m - 1).bit_length()))

    qt = _pad_to(q.swapaxes(1, 2), block_q, 2)            # (B,Hq,Nq',hd)
    kt = _pad_to(k.swapaxes(1, 2), block_k, 2)
    vt = _pad_to(v.swapaxes(1, 2), block_k, 2)
    log_g = _pad_to(log_repeats(g)[None, :], block_k, 1, value=NEG)
    lo = _pad_to(col_lo.astype(jnp.int32)[None, :], block_k, 1,
                 value=np.iinfo(np.int32).max)            # out-of-window too
    hi = _pad_to(col_hi.astype(jnp.int32)[None, :], block_k, 1,
                 value=np.iinfo(np.int32).max)
    rp = _pad_to(row_pos.astype(jnp.int32)[:, None], block_q, 0)

    out = prism_flash_attention(
        qt, kt, vt, log_g, lo, hi, rp,
        causal=causal, prefix_len=prefix_len, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :, :nq].swapaxes(1, 2)                  # (B,Nq,Hq,hd)

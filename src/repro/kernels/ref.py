"""Pure-jnp oracle for the PRISM scaling-aware flash-attention kernel.

Materializes the full (Nq, M) logits, applies the ``+log g`` column bias and
the position-range visibility mask, and runs a stable softmax — the direct
transcription of paper Eq. 13–15 + Eq. 17 that the Pallas kernel must match
(tests sweep shapes/dtypes with ``interpret=True``)."""
from __future__ import annotations

import jax.numpy as jnp

from ..core.attention import _gqa_logits, _gqa_output
from ..core.masks import NEG_INF


def prism_attention_reference(
    q,            # (B, Nq, Hq, hd)
    k,            # (B, M, Hkv, hd)
    v,            # (B, M, Hkv, hd)
    log_g,        # (M,) float32 — log repeat counts; -inf(=NEG_INF) on padding
    col_lo,       # (M,) int32 global position ranges per column
    col_hi,       # (M,) int32
    row_pos,      # (Nq,) int32 global positions of query rows
    *,
    causal: bool,
    prefix_len: int = 0,
    window: int | None = None,
    scale: float | None = None,
):
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    s = _gqa_logits(q, k, scale).astype(jnp.float32)     # (B, Hq, Nq, M)
    s = s + log_g[None, None, None, :]
    if causal:
        vis = col_hi[None, :] <= row_pos[:, None]
        if prefix_len > 0:
            vis = vis | (col_hi[None, :] < prefix_len)
    else:
        vis = jnp.ones((row_pos.shape[0], col_lo.shape[0]), bool)
    if window is not None:
        vis = vis & (col_lo[None, :] > row_pos[:, None] - window)
    s = jnp.where(vis[None, None], s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    e = jnp.where(vis[None, None] & (log_g > NEG_INF / 2)[None, None, None],
                  e, 0.0)            # fully-masked rows -> 0, not uniform
    w = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-30)
    return _gqa_output(w.astype(v.dtype), v)

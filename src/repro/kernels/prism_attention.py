"""Pallas TPU kernel: PRISM scaling-aware flash attention.

The paper's restructured softmax (Eq. 13–15) folded into a streaming
(flash) softmax:

  * the repeat-count scaling ``Ψ ⊙ g`` becomes a ``+log g`` additive column
    bias inside the running max/sum — duplicates are never materialized, so
    K/V tiles stay ``N_p + (P-1)·L`` long (the entire compute saving);
  * the partition-aware causal mask (Eq. 17) is evaluated *positionally*
    from per-column (lo, hi) global-position ranges — no (Nq, M) mask array
    ever touches HBM;
  * ``g = 0`` (log g = -1e30) doubles as the padding mask for ragged tiles.

Tiling: grid (B·Hq, Nq/blk_q, M/blk_k), K innermost and sequential; the
running max ``m``, normalizer ``l`` and accumulator live in VMEM scratch
across K steps.  Block shapes default to 128 (MXU-aligned); hd up to 256
keeps q/k/v tiles ≤ 128·256·4B = 128 KiB each, comfortably inside the
~16 MiB v5e VMEM alongside scores and accumulator.

GQA is handled in the K/V BlockSpec index maps (query head → KV head), so
grouped heads share K/V tiles without materializing the repeat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(rowpos_ref, collo_ref, colhi_ref, logg_ref,
            q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr,
            *, scale, causal, prefix_len, window, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]                                   # (blk_q, hd)
    k = k_ref[...]                                   # (blk_k, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (blk_q, blk_k)

    row = rowpos_ref[...].astype(jnp.int32)          # (blk_q, 1)
    lo = collo_ref[...].astype(jnp.int32)            # (1, blk_k)
    hi = colhi_ref[...].astype(jnp.int32)
    logg = logg_ref[...].astype(jnp.float32)         # (1, blk_k)

    if causal:
        vis = hi <= row                              # (blk_q, blk_k)
        if prefix_len > 0:
            vis = vis | (hi < prefix_len)
    else:
        vis = jnp.ones(s.shape, bool)
    if window is not None:
        vis = vis & (lo > row - window)

    s = jnp.where(vis, s + logg, NEG)

    m_prev = m_scr[...]                              # (blk_q, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                           # (blk_q, blk_k)
    # fully-masked tiles: m_new == NEG makes exp(NEG-NEG)=1 — re-zero so
    # such rows end with l=0 and a 0 output instead of uniform garbage
    p = jnp.where(s > NEG / 2, p, 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def prism_flash_attention(
    q,            # (B, Hq, Nq, hd)
    k,            # (B, Hkv, M, hd)
    v,            # (B, Hkv, M, hd)
    log_g,        # (1, M) float32; NEG on padding columns
    col_lo,       # (1, M) int32
    col_hi,       # (1, M) int32
    row_pos,      # (Nq, 1) int32
    *,
    causal: bool,
    prefix_len: int = 0,
    window: int | None = None,
    scale: float,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    b, hq, nq, hd = q.shape
    _, hkv, m, _ = k.shape
    assert hq % hkv == 0
    grp = hq // hkv
    assert nq % block_q == 0 and m % block_k == 0, (nq, m, block_q, block_k)
    nqb, nkb = nq // block_q, m // block_k
    grid = (b * hq, nqb, nkb)

    def q_map(bh, qi, ki):
        return (bh // hq, bh % hq, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // hq, (bh % hq) // grp, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, prefix_len=prefix_len,
        window=window, nk=nkb)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, 1), lambda bh, qi, ki: (qi, 0)),
            pl.BlockSpec((1, block_k), lambda bh, qi, ki: (0, ki)),
            pl.BlockSpec((1, block_k), lambda bh, qi, ki: (0, ki)),
            pl.BlockSpec((1, block_k), lambda bh, qi, ki: (0, ki)),
            pl.BlockSpec((None, None, block_q, hd), q_map),
            pl.BlockSpec((None, None, block_k, hd), kv_map),
            pl.BlockSpec((None, None, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b, hq, nq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(row_pos, col_lo, col_hi, log_g, q, k, v)

from .pipeline import CharTokenizer, synthetic_text, lm_batches, classification_batches  # noqa: F401

"""Deterministic offline data pipeline.

No network access in this environment, so the char-level LM experiments run
on a synthetic-but-structured corpus (a Markov-ish text generator with
long-range repeats — enough statistical structure that a small LM's bpc
responds measurably to attention-quality degradation, which is what the
accuracy-vs-CR reproduction needs)."""
from __future__ import annotations

import numpy as np

_WORDS = (
    "the of and a to in is was he for it with as his on be at by had not "
    "are but from or have an they which one you were her all she there "
    "would their we him been has when who will more no if out so said what "
    "time up go about than into could state only new year some take come "
    "these know see use get like then first any work now may such give over "
    "think most even find day also after way many must look before great "
    "back through long where much should well people down own just because "
    "good each those feel seem how high too place little world very still "
    "nation hand old life tell write become here show house both between "
    "need mean call develop under last right move thing general school never "
    "same another begin while number part turn real leave might want point"
).split()


class CharTokenizer:
    """Byte-level tokenizer over printable ASCII (vocab 97 + pad)."""

    def __init__(self):
        self.chars = ["<pad>"] + [chr(c) for c in range(32, 127)] + ["\n"]
        self.vocab = len(self.chars)
        self._enc = {c: i for i, c in enumerate(self.chars)}

    def encode(self, text: str) -> np.ndarray:
        return np.asarray([self._enc.get(c, 1) for c in text], np.int32)

    def decode(self, ids) -> str:
        return "".join(self.chars[int(i)] for i in ids if int(i) > 0)


def synthetic_text(n_chars: int, seed: int = 0) -> str:
    """Zipf-weighted word stream with sentence structure and long-range
    phrase repeats (text8-flavoured)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    probs = (1 / ranks) / (1 / ranks).sum()
    out, phrases = [], []
    count = 0
    while count < n_chars:
        if phrases and rng.random() < 0.15:           # long-range repeat
            words = phrases[rng.integers(len(phrases))]
        else:
            words = list(rng.choice(_WORDS, size=rng.integers(4, 9), p=probs))
            if len(phrases) < 64:
                phrases.append(words)
        s = " ".join(words)
        if rng.random() < 0.2:
            s += "."
        out.append(s)
        count += len(s) + 1
    return " ".join(out)[:n_chars]


def lm_batches(tokens: np.ndarray, *, batch: int, seq: int, seed: int = 0):
    """Infinite iterator of (inputs, labels) next-char pairs."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    assert n > 0, "corpus shorter than seq_len"
    while True:
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield x, y


def classification_batches(*, batch: int, seq: int, n_classes: int,
                           vocab: int, seed: int = 0):
    """Synthetic sequence-classification task (ViT/BERT-style smoke): the
    label is a function of token statistics so it is actually learnable."""
    rng = np.random.default_rng(seed)
    while True:
        x = rng.integers(1, vocab, size=(batch, seq), dtype=np.int64)
        y = (x.sum(axis=1) % n_classes).astype(np.int64)
        yield x.astype(np.int32), y.astype(np.int32)

"""Paper Fig. 5 — end-to-end latency vs network bandwidth (ViT, B=1).

The paper ran 2×2.1 GHz CPU cores per device; we measure THIS machine's
actual single-device ViT forward wall time, scale per-mode compute by the
analytic FLOP ratio (the machine's achieved flops/s cancels), and add the
serial communication term bytes/bandwidth per Transformer block.  Output:
latency(bandwidth) for single / voltage / prism — the paper's crossover
(Voltage worse than single-device at low bandwidth, PRISM better
everywhere) must reproduce.
"""
from __future__ import annotations

import numpy as np

from .common import (VIT_B16 as S, comm_bytes_total, model_flops, timeit)

BANDWIDTHS_MBPS = (50, 100, 200, 400, 600, 800, 1000)

POINTS = [
    ("single", 1, 0),
    ("voltage", 2, 0),
    ("voltage", 3, 0),
    ("prism", 2, 10),     # paper: CR=9.9
    ("prism", 3, 10),     # paper: CR=6.55 (PDPLC 20 -> L=10)
]


def measure_single_forward_s() -> float:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("vit-b16")
    params = T.init(cfg, jax.random.PRNGKey(0))
    embeds = jax.random.normal(jax.random.PRNGKey(1), (1, 197, cfg.d_model))

    @jax.jit
    def fwd(p, e):
        logits, _ = T.forward(cfg, p, None, embeds=e)
        return logits
    return timeit(lambda: fwd(params, embeds).block_until_ready(),
                  warmup=2, iters=5) / 1e6


def rows():
    t_single = measure_single_forward_s()
    base_flops = model_flops(S, "single", 1, 0)["per_device_gflops"]
    out = []
    for mode, p, L in POINTS:
        f = model_flops(S, mode, p, L)["per_device_gflops"]
        t_comp = t_single * f / base_flops
        comm = comm_bytes_total(S, mode, p, L)
        for bw in BANDWIDTHS_MBPS:
            t_comm = comm * 8 / (bw * 1e6)
            out.append({
                "mode": f"{mode}-P{p}" + (f"-L{L}" if L else ""),
                "bandwidth_mbps": bw,
                "t_compute_ms": round(t_comp * 1e3, 2),
                "t_comm_ms": round(t_comm * 1e3, 2),
                "t_total_ms": round((t_comp + t_comm) * 1e3, 2),
            })
    return out, t_single


def main(report):
    out, t_single = rows()
    report("fig5/single_device_forward", t_single * 1e6, "measured")
    by_mode = {}
    for r in out:
        by_mode.setdefault(r["mode"], []).append(r)
    for mode, rs in by_mode.items():
        lat = " ".join(f"{r['bandwidth_mbps']}Mbps:{r['t_total_ms']}ms"
                       for r in rs)
        report(f"fig5/latency/{mode}", 0.0, lat)
    # the paper's qualitative claims, asserted:
    lat200 = {m: next(r["t_total_ms"] for r in rs
                      if r["bandwidth_mbps"] == 200)
              for m, rs in by_mode.items()}
    single = lat200["single-P1"]
    assert lat200["prism-P2-L10"] < single, lat200
    assert lat200["voltage-P2"] > lat200["prism-P2-L10"], lat200
    report("fig5/claim/prism_beats_single_at_200mbps", 0.0,
           f"{lat200['prism-P2-L10']} < {single}")
    report("fig5/claim/prism_beats_voltage_at_200mbps", 0.0,
           f"{lat200['prism-P2-L10']} < {lat200['voltage-P2']}")

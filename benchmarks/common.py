"""Shared benchmark machinery: wall-clock timer and the analytic FLOP /
communication model used by the paper's tables (IV, V, VI).

The FLOP model counts 2 flops per MAC over the actual PRISM per-device
shapes: Q from the local partition (N_p rows), K/V from the augmented
matrix (M = N_p + (P-1)·L rows for PRISM, M = N for Voltage — the
baseline's redundant K/V computation), scores/AV over (N_p × M), and the
position-wise FFN over N_p rows.  This is the quantity the paper reports
as 'GFLOPs /device'.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


def timeit(fn, *, warmup=1, iters=5):
    """Median wall-clock microseconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


@dataclass(frozen=True)
class EncSpec:
    """Uniform encoder/decoder transformer for the analytic model."""
    n_layers: int
    d: int            # d_model
    h: int            # heads
    hd: int           # head dim
    d_ff: int
    n: int            # sequence length
    vocab: int = 0
    n_classes: int = 0
    gated: bool = False
    patch_in: int = 0  # ViT patch-embedding input features


def layer_flops_device(s: EncSpec, n_p: int, m: int) -> float:
    """One Transformer block on one device: local queries n_p, K/V source
    rows m (the augmented matrix)."""
    dh = s.h * s.hd
    f = 0.0
    f += 2 * n_p * s.d * dh                # W_q
    f += 2 * 2 * m * s.d * dh              # W_k, W_v  (the PRISM saving)
    f += 2 * n_p * m * dh                  # Q K^T
    f += 2 * n_p * m * dh                  # S V
    f += 2 * n_p * dh * s.d                # W_o
    ff_mults = 3 if s.gated else 2
    f += 2 * ff_mults * n_p * s.d * s.d_ff  # FFN
    return f


def model_flops(s: EncSpec, mode: str, p: int, L: int) -> dict:
    """Total + per-device forward GFLOPs for a partitioning mode."""
    if mode == "single":
        p_eff, n_p, m = 1, s.n, s.n
    elif mode == "voltage":
        p_eff, n_p, m = p, -(-s.n // p), s.n
    elif mode == "prism":
        n_p = -(-s.n // p)
        p_eff, m = p, n_p + (p - 1) * L
    else:
        raise ValueError(mode)
    per_dev = s.n_layers * layer_flops_device(s, n_p, m)
    # embedding / head (on the master or replicated; count once)
    extra = 0.0
    if s.patch_in:
        extra += 2 * s.n * s.patch_in * s.d
    if s.n_classes:
        extra += 2 * s.d * s.n_classes
    if s.vocab:
        extra += 2 * s.n * s.d * s.vocab     # LM head (tied)
    total = p_eff * per_dev + extra
    return {"total_gflops": total / 1e9,
            "per_device_gflops": (per_dev + extra / p_eff) / 1e9}


def comm_elements(s: EncSpec, mode: str, p: int, L: int) -> float:
    """Per-device per-layer transmitted elements (paper §IV-C)."""
    if mode == "single" or p == 1:
        return 0.0
    if mode == "voltage":
        return (p - 1) * s.n * s.d / p
    return (p - 1) * L * s.d


def comm_bytes_total(s: EncSpec, mode: str, p: int, L: int,
                     bytes_per_el: int = 4) -> float:
    """Whole-model per-device communication volume (unicast, as in the
    paper's comparison)."""
    return s.n_layers * comm_elements(s, mode, p, L) * bytes_per_el


def speedup(base: float, ours: float) -> float:
    return 100.0 * (1.0 - ours / base) if base else 0.0


VIT_B16 = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=197,
                  n_classes=1000, patch_in=16 * 16 * 3)
BERT_BASE = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=256,
                    n_classes=2)
GPT2_SMALL = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=350,
                     vocab=50257)

"""Shared benchmark machinery: wall-clock timer and the analytic FLOP /
communication model used by the paper's tables (IV, V, VI).

The FLOP model counts 2 flops per MAC over the actual PRISM per-device
shapes: Q from the local partition (N_p rows), K/V from the augmented
matrix (M = N_p + (P-1)·L rows for PRISM, M = N for Voltage — the
baseline's redundant K/V computation), scores/AV over (N_p × M), and the
position-wise FFN over N_p rows.  This is the quantity the paper reports
as 'GFLOPs /device'.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


def timeit(fn, *, warmup=1, iters=5):
    """Median wall-clock microseconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


@dataclass(frozen=True)
class EncSpec:
    """Uniform encoder/decoder transformer for the analytic model."""
    n_layers: int
    d: int            # d_model
    h: int            # heads
    hd: int           # head dim
    d_ff: int
    n: int            # sequence length
    vocab: int = 0
    n_classes: int = 0
    gated: bool = False
    patch_in: int = 0  # ViT patch-embedding input features


def layer_flops_device(s: EncSpec, n_p: int, m: int) -> float:
    """One Transformer block on one device: local queries n_p, K/V source
    rows m (the augmented matrix)."""
    dh = s.h * s.hd
    f = 0.0
    f += 2 * n_p * s.d * dh                # W_q
    f += 2 * 2 * m * s.d * dh              # W_k, W_v  (the PRISM saving)
    f += 2 * n_p * m * dh                  # Q K^T
    f += 2 * n_p * m * dh                  # S V
    f += 2 * n_p * dh * s.d                # W_o
    ff_mults = 3 if s.gated else 2
    f += 2 * ff_mults * n_p * s.d * s.d_ff  # FFN
    return f


def model_flops(s: EncSpec, mode: str, p: int, L: int) -> dict:
    """Total + per-device forward GFLOPs for a partitioning mode."""
    if mode == "single":
        p_eff, n_p, m = 1, s.n, s.n
    elif mode == "voltage":
        p_eff, n_p, m = p, -(-s.n // p), s.n
    elif mode == "prism":
        n_p = -(-s.n // p)
        p_eff, m = p, n_p + (p - 1) * L
    else:
        raise ValueError(mode)
    per_dev = s.n_layers * layer_flops_device(s, n_p, m)
    # embedding / head (on the master or replicated; count once)
    extra = 0.0
    if s.patch_in:
        extra += 2 * s.n * s.patch_in * s.d
    if s.n_classes:
        extra += 2 * s.d * s.n_classes
    if s.vocab:
        extra += 2 * s.n * s.d * s.vocab     # LM head (tied)
    total = p_eff * per_dev + extra
    return {"total_gflops": total / 1e9,
            "per_device_gflops": (per_dev + extra / p_eff) / 1e9}


def comm_elements(s: EncSpec, mode: str, p: int, L: int) -> float:
    """Per-device per-layer transmitted elements (paper §IV-C)."""
    if mode == "single" or p == 1:
        return 0.0
    if mode == "voltage":
        return (p - 1) * s.n * s.d / p
    return (p - 1) * L * s.d


def comm_bytes_total(s: EncSpec, mode: str, p: int, L: int,
                     bytes_per_el: int = 4) -> float:
    """Whole-model per-device communication volume (unicast, as in the
    paper's comparison)."""
    return s.n_layers * comm_elements(s, mode, p, L) * bytes_per_el


def cached_attn_layer_flops(*, d: int, h: int, hd: int, hkv: int,
                            d_ff: int, gated: bool, nq: int,
                            m: int) -> float:
    """One Transformer block on the SERVING path: ``nq`` new tokens are
    projected (Q and K/V — the cache already holds every earlier K/V
    row, unlike ``layer_flops_device`` where the teacher-forced prefill
    recomputes all m rows) and attended against ``m`` cached source
    rows.  This is the deterministic cost model the engine-throughput
    bench uses for its logical clock: one decode step, one prefill
    chunk, and one padded flush are all instances with different
    (nq, m) — so the chunked-vs-padded comparison and the CI
    bench-regression gate are free of wall-clock noise."""
    dh = h * hd
    dkv = hkv * hd
    f = 2.0 * nq * d * dh                  # W_q
    f += 2.0 * 2 * nq * d * dkv            # W_k, W_v (new tokens only)
    f += 2.0 * nq * m * dh                 # Q K^T
    f += 2.0 * nq * m * dh                 # S V
    f += 2.0 * nq * dh * d                 # W_o
    ff_mults = 3 if gated else 2
    f += 2.0 * ff_mults * nq * d * d_ff    # FFN
    return f


def serve_step_flops(cfg, *, rows: int, nq_per_row: int, m: int,
                     lm_head: bool = False) -> float:
    """Whole-model serving-step FLOPs for a ``repro`` ModelConfig:
    ``rows`` batch rows each contributing ``nq_per_row`` new tokens
    against ``m`` cached columns.  ``lm_head`` adds the output-vocab
    matmul (decode pays it every step; prefill chunks return no
    logits)."""
    f = cfg.n_layers * cached_attn_layer_flops(
        d=cfg.d_model, h=cfg.n_heads, hd=cfg.hd, hkv=cfg.n_kv_heads,
        d_ff=cfg.d_ff, gated=cfg.mlp_kind in ("swiglu", "geglu"),
        nq=rows * nq_per_row, m=m)
    if lm_head:
        f += 2.0 * rows * cfg.d_model * cfg.vocab_size
    return f


def packed_step_flops(cfg, *, decode_tokens: int, prefill_tokens: int,
                      m_decode: int, m_prefill: int) -> float:
    """One token-packed engine tick: cost scales with the REAL packed
    tokens, not ``n_slots × chunk_len``.  Every decode token is one new
    query against up to ``m_decode`` cached columns plus the LM head
    row it must pay (the engine samples it); every prompt token is one
    new query against its prefill region (``m_prefill`` columns) with
    no sampled head (the packed program's LM head runs over the decode
    prefix only).  The engine never launches the packed program with
    zero real tokens (it falls through to the plain decode step or
    reports idle).

    Honest caveat: this counts LOGICAL work.  A compiled packed
    program has the static shape ``(token_budget,)``, so dead tail
    entries of an under-full tick still occupy matmul rows on real
    hardware; the model assumes the deployment sizes its budget to the
    live load (the engine's program cache is keyed by
    ``(kind, token_budget)`` precisely so several budget-sized
    programs can coexist).  On the saturated trace — the regime the
    packed gates certify — ticks run full and logical ≈ static cost;
    the bench also keeps every mode's budget fixed and identical, so
    no comparison is won by budget tuning."""
    f = 0.0
    if decode_tokens:
        f += serve_step_flops(cfg, rows=decode_tokens, nq_per_row=1,
                              m=m_decode, lm_head=True)
    if prefill_tokens:
        f += serve_step_flops(cfg, rows=prefill_tokens, nq_per_row=1,
                              m=m_prefill)
    return f


def speedup(base: float, ours: float) -> float:
    return 100.0 * (1.0 - ours / base) if base else 0.0


VIT_B16 = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=197,
                  n_classes=1000, patch_in=16 * 16 * 3)
BERT_BASE = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=256,
                    n_classes=2)
GPT2_SMALL = EncSpec(n_layers=12, d=768, h=12, hd=64, d_ff=3072, n=350,
                     vocab=50257)

"""Aggregate dry-run JSONL records into the §Roofline markdown table."""
from __future__ import annotations

import json
import os


def load(path):
    if not os.path.exists(path):
        return []
    rows = [json.loads(l) for l in open(path)]
    # keep the latest record per (arch, shape, mesh, mode)
    out = {}
    for r in rows:
        out[(r["arch"], r["shape"], r["mesh"], r["mode"])] = r
    return list(out.values())


def markdown(rows, title):
    lines = [f"### {title}", "",
             "| arch | shape | mesh | t_compute(ms) | t_memory(ms) | "
             "t_collective(ms) | bound | MODEL_FLOPS | useful | "
             "peak_live(GB) |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    for r in sorted(rows, key=lambda r: (r["arch"], order[r["shape"]])):
        peak = r.get("mem_peak_bytes") or r.get("mem_temp_bytes") or 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {1e3 * r['t_compute']:.2f} | {1e3 * r['t_memory']:.2f} "
            f"| {1e3 * r['t_collective']:.2f} | {r['bottleneck']} "
            f"| {r['model_flops']:.2e} | {r['useful']:.3f} "
            f"| {peak / 1e9:.1f} |")
    return "\n".join(lines)


def main(report):
    for path, title in (("results_singlepod.jsonl", "single-pod 16x16"),
                        ("results_multipod.jsonl", "multi-pod 2x16x16")):
        rows = load(path)
        report(f"roofline/{title.split()[0]}/rows", 0.0, str(len(rows)))
        for r in rows:
            report(
                f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}", 0.0,
                f"comp={1e3 * r['t_compute']:.2f}ms "
                f"mem={1e3 * r['t_memory']:.2f}ms "
                f"coll={1e3 * r['t_collective']:.2f}ms "
                f"-> {r['bottleneck']}")


if __name__ == "__main__":
    for p in ("results_singlepod.jsonl", "results_multipod.jsonl"):
        print(markdown(load(p), p))

"""Paper Table V — BERT computation & communication efficiency.

Operating points from the paper: P=2 with PDPLC ∈ {13, 1} (CR ≈ 9.85 /
128) and P=3 with PDPLC ∈ {18, 2} (CR ≈ 9.48 / 85.3).  GLUE accuracy
columns are covered by accuracy_vs_cr (offline datasets); this table
reproduces the GFLOPs / speed-up / communication columns.
"""
from __future__ import annotations

from .common import BERT_BASE as S, model_flops, comm_elements, speedup

ROWS = [
    ("single", 1, 0),
    ("voltage", 2, 0),
    ("voltage", 3, 0),
    ("prism", 2, 13),
    ("prism", 2, 1),
    ("prism", 3, 18),
    ("prism", 3, 2),
]

PAPER = {
    ("single", 1, 0): (45.93, 45.93),
    ("voltage", 2, 0): (53.18, 26.59),
    ("voltage", 3, 0): (60.42, 20.14),
    ("prism", 2, 13): (45.58, 22.79),
    ("prism", 2, 1): (44.79, 22.40),
    ("prism", 3, 18): (46.02, 15.34),
    ("prism", 3, 2): (44.51, 14.84),
}

PAPER_COMM = {("prism", 2, 13): 89.84, ("prism", 2, 1): 99.22,
              ("prism", 3, 18): 89.47, ("prism", 3, 2): 98.83}


def rows():
    base = model_flops(S, "single", 1, 0)["per_device_gflops"]
    out = []
    for mode, p, pdplc in ROWS:
        L = pdplc // max(1, p - 1) if pdplc else 0
        f = model_flops(S, mode, p, L)
        volt = comm_elements(S, "voltage", p, 0)
        ours = comm_elements(S, mode, p, L)
        cr = (S.n / (L * p)) if L else float("nan")
        pt, pd = PAPER.get((mode, p, pdplc), (float("nan"),) * 2)
        out.append({
            "strategy": mode, "P": p, "PDPLC": pdplc,
            "total_gflops": round(f["total_gflops"], 2),
            "per_device_gflops": round(f["per_device_gflops"], 2),
            "comp_speedup_pct": round(
                speedup(base, f["per_device_gflops"]), 2),
            "CR": round(cr, 2) if L else "-",
            "comm_speedup_pct": round(speedup(volt, ours), 2)
            if p > 1 else "-",
            "paper_total": pt, "paper_per_dev": pd,
            "paper_comm": PAPER_COMM.get((mode, p, pdplc), "-"),
        })
    return out


def main(report):
    for r in rows():
        name = f"table5/bert/{r['strategy']}-P{r['P']}-L{r['PDPLC']}"
        report(name, 0.0,
               f"GF={r['total_gflops']}(paper {r['paper_total']}) "
               f"/dev={r['per_device_gflops']}(paper {r['paper_per_dev']}) "
               f"comp+{r['comp_speedup_pct']}% "
               f"comm+{r['comm_speedup_pct']}%(paper {r['paper_comm']})")

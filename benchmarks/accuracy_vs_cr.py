"""Accuracy-vs-CR reproduction on a trained char-LM (paper Tables II/VI
and Fig. 4 trends, on compute we actually have — CBT/CIFAR/GLUE are
unavailable offline).

1. Train a small GPT-style char-LM on the synthetic corpus (FullContext).
2. Evaluate bits-per-char teacher-forced under the SIMULATED P-device
   PRISM protocol at CR ∈ {1, 2, 4, 8} × P ∈ {2, 3, 4}:
     - bpc must degrade monotonically (minor at low CR) — Table VI trend;
     - CR=1 must equal the single-device bpc exactly — exactness property;
     - 'prism' (≡ duplicated) must beat 'prism_nodup' — Table II;
3. Fine-tune WITH PRISM in the loop at the most aggressive setting and
   show bpc recovery — the paper's fine-tuning claim (§V-D).
"""
from __future__ import annotations

import math

SEQ, BATCH = 120, 16          # SEQ divisible by P ∈ {2, 3, 4}


class Harness:
    def __init__(self):
        import jax
        import jax.numpy as jnp
        from repro.data.pipeline import (CharTokenizer, lm_batches,
                                         synthetic_text)
        from repro.models import transformer as T
        from repro.models.config import ModelConfig
        from repro.models.context import SimulatedContext
        from repro.optim import (adamw_init, adamw_update,
                                 clip_by_global_norm)
        self.jax, self.jnp, self.T = jax, jnp, T
        self.SimulatedContext = SimulatedContext
        self.adamw_update = adamw_update
        self.clip = clip_by_global_norm

        tok = CharTokenizer()
        self.train_it = lm_batches(tok.encode(synthetic_text(200_000, 1)),
                                   batch=BATCH, seq=SEQ, seed=0)
        held_it = lm_batches(tok.encode(synthetic_text(20_000, 2)),
                             batch=BATCH, seq=SEQ, seed=9)
        self.eval_batches = [next(held_it) for _ in range(8)]
        self.cfg = ModelConfig(
            name="char-lm", arch_type="dense", n_layers=4, d_model=128,
            n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
            vocab_size=tok.vocab, mlp_kind="gelu", norm_kind="rmsnorm",
            pos="rope", tie_embeddings=True)
        self.params = T.init(self.cfg, jax.random.PRNGKey(0))
        self.opt = adamw_init(self.params)

    def loss(self, params, x, y, ctx_cfg=None):
        jnp = self.jnp
        ctx = self.SimulatedContext(ctx_cfg) if ctx_cfg is not None else None
        logits, _ = self.T.forward(self.cfg, params, x, ctx=ctx, chunk=8)
        lse = self.jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), y[..., None], -1)[..., 0]
        return (lse - gold).mean()

    def train(self, steps, ctx_cfg=None, lr=3e-3):
        jax, jnp = self.jax, self.jnp

        def step(params, opt, x, y):
            loss, grads = jax.value_and_grad(
                lambda p: self.loss(p, x, y, ctx_cfg))(params)
            grads, _ = self.clip(grads, 1.0)
            params, opt = self.adamw_update(params, grads, opt, lr=lr,
                                            weight_decay=0.01)
            return params, opt, loss
        jstep = jax.jit(step)
        first = last = None
        for _ in range(steps):
            x, y = next(self.train_it)
            self.params, self.opt, loss = jstep(
                self.params, self.opt, jnp.asarray(x), jnp.asarray(y))
            last = float(loss)
            first = first if first is not None else last
        return first, last

    def bpc(self, ctx_cfg=None, params=None):
        jax, jnp = self.jax, self.jnp
        params = self.params if params is None else params
        f = jax.jit(lambda p, x, y: self.loss(p, x, y, ctx_cfg))
        tot = sum(float(f(params, jnp.asarray(x), jnp.asarray(y)))
                  for x, y in self.eval_batches)
        return tot / len(self.eval_batches) / math.log(2)


def main(report):
    from repro.core.protocol import PrismConfig
    h = Harness()
    first, last = h.train(400)
    report("accuracy/train/final_loss", 0.0,
           f"step400 loss={last:.3f} (start {first:.3f})")
    assert last < first * 0.7, "char-LM failed to train"

    base = h.bpc()
    report("accuracy/bpc/single", 0.0, f"{base:.4f}")

    # exactness: CR=1 (L = N_p) == single-device
    for p in (2, 3, 4):
        b = h.bpc(PrismConfig(P=p, L=SEQ // p))
        report(f"accuracy/bpc/P{p}-CR1-exact", 0.0,
               f"{b:.4f} (single {base:.4f})")
        assert abs(b - base) < 5e-3, (p, b, base)

    # CR sweep: monotonic minor degradation (Table VI / Fig. 4 trend)
    trend_ok = True
    for p in (2, 3):
        prev = base
        for cr in (2, 4, 8):
            b = h.bpc(PrismConfig(P=p, cr=float(cr)))
            report(f"accuracy/bpc/P{p}-CR{cr}", 0.0,
                   f"{b:.4f} (Δ={b - base:+.4f})")
            trend_ok &= b >= prev - 2e-2
            prev = b
    report("accuracy/trend/monotonic_degradation", 0.0, str(trend_ok))

    # Table II: duplication (prism ≡ duplicated) vs no duplication
    for p, cr in ((2, 4.0), (3, 4.0)):
        b_dup = h.bpc(PrismConfig(P=p, cr=cr, mode="prism"))
        b_nod = h.bpc(PrismConfig(P=p, cr=cr, mode="prism_nodup"))
        report(f"accuracy/table2/P{p}-CR{cr}", 0.0,
               f"duplicated={b_dup:.4f} nodup={b_nod:.4f} "
               f"{'OK(dup-better)' if b_dup <= b_nod else 'UNEXPECTED'}")

    # fine-tune WITH PRISM at the most aggressive setting (paper §V-D)
    hard = PrismConfig(P=3, cr=8.0)
    before = h.bpc(hard)
    h.train(150, ctx_cfg=hard, lr=1e-3)
    after = h.bpc(hard)
    report("accuracy/finetune/P3-CR8", 0.0,
           f"before={before:.4f} after={after:.4f} "
           f"{'OK(recovered)' if after < before else 'UNEXPECTED'}")

"""Serving-engine benchmark: token-packed ticks vs chunked prefill vs
padded flushes vs static batching, on staggered-arrival traces.

Replays identical Poisson traces through ServingEngine instances that
differ only in admission policy:

  * packed  — the system: ONE compiled program per engine tick over a
              flat token batch of every live decode token + prompt
              tokens from every mid-prefill request (Sarathi-style
              token-budget planning); per-tick cost ∝ REAL tokens
  * chunked — the PR-4 path: prompts prefilled ``chunk_len`` tokens at
              a time in a full (n_slots, chunk_len) program, chunk
              steps interleaved with decodes
  * padded  — PR-2 continuous batching: one monolithic right-padded
              prefill flush per admission
  * gang    — classic static batching (admit into an empty pool only,
              drain completely): the head-of-line-blocking baseline

Five traces: the moderate-load ``main`` trace (chat regime), the
``short``-prompt trace (pad-to-length waste), the ``saturated`` trace
(arrivals far above the service rate — the regime where PR-4's FLOP
clock recorded gang flushes out-amortizing per-row chunk calls, and
where token packing closes that gap), the shared-``prefix`` trace
(every prompt opens with the same system prompt; the paged engine's
prefix cache maps the shared pages copy-on-write and must cut prefill
work without changing a token), and the ``overload`` trace (arrivals
demand more KV pages than the pool holds; the host offload tier must
cut the interactive class's TTFT by preempting background decodes —
spill to host, restore later — without changing a token).

To keep the comparison deterministic on noisy shared CPUs — and
gateable in CI (``benchmarks/compare.py``) — the engines run on a
*logical* clock whose step costs come from the ANALYTIC FLOP model in
``benchmarks/common.py``: one decode step costs 1 unit; a chunk step
and a padded flush cost their FLOP multiple of a decode step; a packed
tick costs its real-token FLOPs (``packed_step_flops``), read from the
engine's per-tick token counters.  Every logical metric (requests per
kstep, TTFT in steps, prefill FLOPs per request) is a pure function of
the code + trace seed.  Measured wall-clock per step kind is reported
alongside for the wall-time conversions, but nothing gated depends on
it.

The ``stream`` section (PR 9) measures the async streaming loop
(``serving/streaming.py``) both ways: ``stream_token_match`` drives the
double-buffered engine on the logical clock over the identical main
trace and requires token-identical streams, and the wall-clock sweep
(``run_stream_wall``) replays a Poisson trace at three offered loads
with overlap on vs off on REAL time — TTFT/ITL percentiles in seconds
plus ``host_overhead_fraction``, the host-bookkeeping share of the
loop's non-idle wall time (docs/streaming.md defines the measurement
model).  The wall numbers are hardware-dependent and only
coarse-gated (fraction < 0.9); token identity is gated exactly.

Run standalone (writes the ``BENCH_engine.json`` artifact)::

    PYTHONPATH=src python -m benchmarks.engine_throughput \
        --json BENCH_engine.json

or via the harness (``python -m benchmarks.run --only engine``).
"""
from __future__ import annotations

import time

N_SLOTS, PREFILL_LEN, MAX_CACHE = 4, 32, 96
CHUNK_LEN, DECODE_PER_PREFILL = 8, 2
TOKEN_BUDGET = N_SLOTS + CHUNK_LEN


class StepClock:
    """Logical clock in decode-step units, advanced by the drive loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def bench_config():
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="bench-dense", arch_type="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)


def logical_costs(cfg) -> dict:
    """Analytic per-step costs in decode-step units (deterministic)."""
    from .common import serve_step_flops
    decode = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=1,
                              m=MAX_CACHE, lm_head=True)
    chunk = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=CHUNK_LEN,
                             m=PREFILL_LEN)
    flush = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=PREFILL_LEN,
                             m=PREFILL_LEN, lm_head=True)
    return {"decode": 1.0, "chunk": chunk / decode,
            "padded_flush": flush / decode, "decode_flops": decode}


def prefill_flops_per_request(cfg, plens, mode: str) -> float:
    """Mean per-request prefill FLOPs over a trace's prompt lengths:
    packed pays exactly one query per REAL prompt token; chunked pays
    ceil(len/chunk) chunks of chunk_len queries against the prefill
    region; padded always pays the full pad-to-length forward."""
    from .common import serve_step_flops
    total = 0.0
    for plen in plens:
        if mode == "packed":
            total += serve_step_flops(cfg, rows=plen, nq_per_row=1,
                                      m=PREFILL_LEN)
        elif mode == "chunked":
            n_chunks = -(-plen // CHUNK_LEN)
            total += n_chunks * serve_step_flops(
                cfg, rows=1, nq_per_row=CHUNK_LEN, m=PREFILL_LEN)
        else:
            total += serve_step_flops(cfg, rows=1,
                                      nq_per_row=PREFILL_LEN,
                                      m=PREFILL_LEN, lm_head=True)
    return total / max(1, len(plens))


def build_engine(mode: str, *, prefix_cache: bool | None = None,
                 offload: bool = False, n_pages: int | None = None,
                 faults=None, max_restarts: int = 3, wall: bool = False):
    import jax
    from repro.models import transformer as T
    from repro.runtime.serve import ServeHParams
    from repro.serving import EngineConfig, ServingEngine

    cfg = bench_config()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    # wall=True keeps the engine on real time (time.monotonic) — the
    # streaming wall-clock mode measures seconds, not decode-steps
    clock = time.monotonic if wall else StepClock()
    prefill_mode = {"packed": "packed", "padded": "padded"}.get(
        mode, "chunked")
    ecfg = EngineConfig(
        n_slots=N_SLOTS, prefill_len=PREFILL_LEN, max_cache=MAX_CACHE,
        hp=ServeHParams(decode_mode="exact", ssm_chunk=8),
        decode_per_prefill=DECODE_PER_PREFILL,
        chunk_len=CHUNK_LEN, token_budget=TOKEN_BUDGET,
        prefill_mode=prefill_mode, gang=(mode == "gang"),
        prefix_cache=prefix_cache, offload=offload, n_pages=n_pages,
        faults=faults, max_restarts=max_restarts)
    eng = ServingEngine(cfg, mesh, params, ecfg, clock=clock)
    return eng, clock, cfg


def make_trace(cfg, *, n_requests, arrival_gap, plen_range, gen_range,
               seed=0):
    """Shared deterministic Poisson trace: [(arrival, prompt, gen)]."""
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(arrival_gap, size=n_requests))
    out = []
    for i in range(n_requests):
        plen = int(rng.integers(*plen_range))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        out.append((float(arrivals[i]), prompt,
                    int(rng.integers(*gen_range))))
    return out


def make_prefix_trace(cfg, *, n_requests, arrival_gap, prefix_len,
                      suffix_range, gen_range, seed=0):
    """System-prompt trace: every request's prompt opens with the SAME
    ``prefix_len``-token prefix (a shared system prompt) followed by a
    short random suffix.  Arrivals are spaced so requests mostly
    serialize — the first completion registers the prefix pages, every
    later admission maps them copy-on-write and skips prefilling the
    covered tokens."""
    import numpy as np
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, size=prefix_len).tolist()
    arrivals = np.cumsum(rng.exponential(arrival_gap, size=n_requests))
    out = []
    for i in range(n_requests):
        suffix = rng.integers(1, cfg.vocab_size,
                              size=int(rng.integers(*suffix_range)))
        out.append((float(arrivals[i]), prefix + suffix.tolist(),
                    int(rng.integers(*gen_range))))
    return out


def make_overload_trace(cfg, *, seed=4):
    """Overload trace: arrivals demand more pages than the pool holds.
    Eight priority-0 background requests (long generations, 4-6 pages
    each against a 14-page pool) arrive in a burst, then six priority-1
    interactive requests (one page each) land inside the busy window.
    Items are (arrival, prompt, gen, priority) 4-tuples: with the
    offload tier ON a blocked interactive arrival spills the
    lowest-priority longest-remaining decode to host memory and admits
    immediately; OFF it queues until a background request drains."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for _ in range(8):
        t += float(rng.exponential(2.0))
        plen = int(rng.integers(12, 25))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        out.append((t, prompt, int(rng.integers(40, 61)), 0))
    for k in range(6):
        plen = int(rng.integers(4, 9))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        out.append((30.0 + 25.0 * k, prompt, int(rng.integers(4, 9)), 1))
    out.sort(key=lambda item: item[0])
    return out


def run_trace(mode: str, trace, costs, *,
              prefix_cache: bool | None = None, offload: bool = False,
              n_pages: int | None = None) -> tuple:
    """Drive one engine over a trace on the analytic logical clock.
    Returns (logical metrics plus measured wall ms per step kind,
    {trace index: generated token ids}) — the token lists let the
    harness gate packed ≡ chunked token-for-token."""
    import numpy as np
    from repro.serving import EngineStats, SamplingParams
    from .common import packed_step_flops

    eng, clock, cfg = build_engine(mode, prefix_cache=prefix_cache,
                                   offload=offload, n_pages=n_pages)
    # compile warmup outside the measured window (one multi-chunk
    # prompt + one short, through eviction)
    eng.submit(list(range(1, 20)), max_new_tokens=2)
    eng.submit([1, 2, 3], max_new_tokens=2)
    eng.run()
    warmed = len(eng.results())
    eng.stats = EngineStats(n_slots=eng.n_slots)

    t0_trace = clock.t
    for i, item in enumerate(trace):
        arrival, prompt, gen = item[0], item[1], item[2]
        eng.submit(prompt, max_new_tokens=gen,
                   sampling=SamplingParams(seed=i),
                   arrival=t0_trace + arrival,
                   priority=item[3] if len(item) > 3 else 0)

    cost = {"decode": costs["decode"],
            "prefill": (costs["chunk"] if mode != "padded"
                        else costs["padded_flush"])}
    wall = {"decode": [], "prefill": [], "packed": []}
    while eng._sched.has_work or eng._pending:
        d0 = eng.stats.packed_decode_tokens
        p0 = eng.stats.packed_prefill_tokens
        w0 = time.perf_counter()
        kind = eng.step()
        if kind == "packed":
            # cost ∝ the tick's REAL packed tokens, from the engine's
            # own counters — not n_slots × chunk_len
            wall[kind].append(time.perf_counter() - w0)
            clock.t += packed_step_flops(
                cfg,
                decode_tokens=eng.stats.packed_decode_tokens - d0,
                prefill_tokens=eng.stats.packed_prefill_tokens - p0,
                m_decode=MAX_CACHE,
                m_prefill=PREFILL_LEN) / costs["decode_flops"]
        elif kind in cost:
            wall[kind].append(time.perf_counter() - w0)
            clock.t += cost[kind]
        else:                               # idle: jump to next arrival
            clock.t += max(0.0, eng.next_arrival() - eng.now())
    steps = clock.t - t0_trace
    assert len(eng.results()) == len(trace) + warmed

    s = eng.stats.summary()
    med = (lambda xs: 1e3 * float(np.median(xs)) if xs else 0.0)
    results = {rid - warmed: toks for rid, toks in eng.results().items()
               if rid >= warmed}
    # per-priority-class TTFT: the preemption gate compares the
    # interactive class directly, not the pooled percentile
    by_class: dict = {}
    for i, item in enumerate(trace):
        pri = item[3] if len(item) > 3 else 0
        by_class.setdefault(pri, []).append(eng._results[i + warmed].ttft)
    ttft_by_class = {str(p): float(np.median(v))
                     for p, v in sorted(by_class.items())}
    return {
        "requests_per_ksteps": 1e3 * len(trace) / steps,
        "ttft_p50_steps": s["ttft_p50_s"],   # logical-clock units
        "ttft_p90_steps": s["ttft_p90_s"],
        "ttft_p99_steps": s["ttft_p99_s"],
        "ttft_p50_by_class": ttft_by_class,
        "preemptions": s["preemptions"],
        "spilled_pages": s["spilled_pages"],
        "restore_hits": s["restore_hits"],
        "restore_misses": s["restore_misses"],
        "ttft_max_steps": s["ttft_max_s"],
        "occupancy": s["occupancy"],
        "prefills": s["prefills"],
        "prefill_chunks": s["prefill_chunks"],
        "prefill_tokens": s["prefill_tokens"],
        "chunk_tokens_real": s["chunk_tokens_real"],
        "chunk_tokens_padded": s["chunk_tokens_padded"],
        "decode_steps": s["decode_steps"],
        "packed_ticks": s["packed_ticks"],
        "packed_decode_tokens": s["packed_decode_tokens"],
        "packed_prefill_tokens": s["packed_prefill_tokens"],
        "out_of_pages": s["out_of_pages"],
        "prefix_hits": s["prefix_hits"],
        "prefix_tokens_saved": s["prefix_tokens_saved"],
        "restarts": s["restarts"],
        "deadline_miss": s["deadline_miss"],
        "quarantined": s["quarantined"],
        "failed_requests": s["failed_requests"],
        "faults_injected": s["faults_injected"],
        "elapsed_steps": steps,
        "wall_decode_ms": med(wall["decode"]),
        "wall_prefill_ms": med(wall["prefill"]),
        "wall_packed_ms": med(wall["packed"]),
    }, results


def run_chaos(trace, clean_toks, *, seed: int) -> dict:
    """Chaos soak: the page-starved overload trace through the packed
    offload engine with EVERY fault kind enabled (``FaultPlan.chaos``)
    — store put/get loss, page poisoning, admission stalls, tick
    delays.  Not a throughput measurement: the return value carries
    the correctness verdicts compare.py gates —

      * ``token_match``: every request the faulted engine COMPLETED
        emitted exactly the clean run's tokens (per-request seeded
        sampling makes tokens independent of timing, slots, and
        restarts, so recovery is provably lossless);
      * ``zero_leak``: after the drain, page refcounts audit clean,
        every page/state row/slot is back in its pool, and the host
        store holds zero bytes;
      * fault/recovery counters for the report.

    Runs its own drive loop (not ``run_trace``): failed requests mean
    ``results() != trace length``, stalled ticks need a clock bump,
    and a stuck-admission tick must still advance the logical clock."""
    from repro.serving import FaultPlan, SamplingParams

    eng, clock, cfg = build_engine(
        "packed", prefix_cache=False, offload=True, n_pages=14,
        faults=FaultPlan.chaos(seed), max_restarts=8)
    for i, (arrival, prompt, gen, pri) in enumerate(trace):
        eng.submit(prompt, max_new_tokens=gen,
                   sampling=SamplingParams(seed=i), arrival=arrival,
                   priority=pri)
    for _ in range(200_000):
        kind = eng.step()
        if kind != "idle":
            clock.t += 1.0
        elif eng._sched.has_work:
            clock.t += 1.0             # stalled admission: retry
        elif eng._pending:
            clock.t += max(1.0, eng.next_arrival() - eng.now())
        else:
            break
    else:
        raise RuntimeError(f"chaos seed {seed} did not drain")

    results = eng.results()
    failed = eng.failed()
    token_match = all(toks == clean_toks[rid]
                      for rid, toks in results.items())
    kv, store = eng.kv_cache, eng.kv_store
    kv.check()
    zero_leak = (not kv.slot_pages and not kv.slot_state
                 and kv.table.free_pages == kv.paging.n_pages
                 and sorted(kv._state_free)
                 == list(range(kv.paging.n_state_pages))
                 and len(store) == 0 and store.bytes_used == 0
                 and sorted(eng._sched.free_slots) == list(range(N_SLOTS)))
    s = eng.stats.summary()
    return {
        "seed": seed,
        "completed": len(results),
        "failed": len(failed),
        "token_match": bool(token_match),
        "zero_leak": bool(zero_leak),
        "accounted": len(results) + len(failed) == len(trace),
        "faults_injected": s["faults_injected"],
        "injected_by_kind": dict(eng._injector.injected),
        "restarts": s["restarts"],
        "quarantined": s["quarantined"],
        "restore_misses": s["restore_misses"],
        "preemptions": s["preemptions"],
    }


def run_degraded(trace) -> dict:
    """Degraded-availability trace: the packed streaming engine with a
    SCHEDULED ``shard_loss`` fault — the (bench mesh's only) sequence
    shard dies mid-decode, so the degraded window serves entirely from
    the Segment-Means standby replicas before the deterministic
    re-prefill recovery.  Returns the verdicts behind the three
    compare.py gates:

      * ``streams_finite``: every stream closed with exactly its
        requested token count, all finite (the degraded window never
        leaks a NaN or stalls a stream);
      * ``zero_leak``: the drained engine audits clean;
      * ``recovery_token_match``: every request — the ones rewound by
        recovery AND the ones admitted after it — finished
        token-identical to the clean run, with the degraded window
        actually observed (``shard_lost``/``degraded_ticks`` >= 1)."""
    from repro.runtime.faults import FaultSpec
    from repro.serving import (FaultPlan, SamplingParams,
                               StreamingEngine)

    def drive_sync(eng, clock, step):
        for _ in range(200_000):
            kind = step()
            if kind != "idle":
                clock.t += 1.0
            elif eng._sched.has_work:
                clock.t += 1.0
            elif eng._pending:
                clock.t += max(1.0, eng.next_arrival() - eng.now())
            else:
                return
        raise RuntimeError("degraded trace did not drain")

    clean, clock, cfg = build_engine("packed", prefix_cache=False)
    for i, (arrival, prompt, gen) in enumerate(trace):
        clean.submit(prompt, max_new_tokens=gen,
                     sampling=SamplingParams(seed=i), arrival=arrival)
    drive_sync(clean, clock, clean.step)
    clean_toks = clean.results()

    plan = FaultPlan(shard_loss=FaultSpec(at=(12,), shard=0))
    eng, clock, cfg = build_engine("packed", prefix_cache=False,
                                   faults=plan, max_restarts=8)
    seng = StreamingEngine(eng)         # injector forces sync ticks
    streams = {}
    for i, (arrival, prompt, gen) in enumerate(trace):
        _, streams[i] = seng.submit_stream(
            prompt, max_new_tokens=gen, sampling=SamplingParams(seed=i),
            arrival=arrival)
    drive_sync(eng, clock, seng.step)
    seng.drain()
    seng._flush_streams()

    delivered = {i: streams[i].drain() for i in range(len(trace))}
    streams_finite = all(
        len(delivered[i]) == trace[i][2]
        and all(isinstance(t, int) for t in delivered[i])
        and streams[i].finished is not None
        for i in range(len(trace)))
    results = eng.results()
    s = eng.stats.summary()
    token_match = (len(results) == len(trace)
                   and all(toks == clean_toks[rid]
                           for rid, toks in results.items())
                   and not eng.failed()
                   and s["shard_lost"] >= 1
                   and s["degraded_ticks"] >= 1)
    kv = eng.kv_cache
    kv.check()
    zero_leak = (not kv.slot_pages and not kv.slot_state
                 and kv.table.free_pages == kv.paging.n_pages
                 and sorted(kv._state_free)
                 == list(range(kv.paging.n_state_pages))
                 and sorted(eng._sched.free_slots) == list(range(N_SLOTS)))
    return {
        "streams_finite": bool(streams_finite),
        "zero_leak": bool(zero_leak),
        "recovery_token_match": bool(token_match),
        "shard_lost": s["shard_lost"],
        "degraded_ticks": s["degraded_ticks"],
        "restarts": s["restarts"],
        "replica_captures": (eng._replica.stats()["captures"]
                             if eng._replica is not None else 0),
        "injected_by_kind": dict(eng._injector.injected),
    }


def run_stream_match(trace, sync_toks, costs) -> dict:
    """Streamed ≡ synchronous tokens on the identical trace.  Drives a
    ``StreamingEngine`` (overlap ON, depth 2) on the same logical
    StepClock ``run_trace`` uses; greedy per-request seeded sampling
    makes tokens scheduling-independent, so every stream must deliver
    exactly the sync packed engine's token list — the
    ``stream_token_match`` gate."""
    from repro.serving import EngineStats, SamplingParams, StreamingEngine
    from .common import packed_step_flops

    eng, clock, cfg = build_engine("packed")
    seng = StreamingEngine(eng, overlap=True)
    eng.submit(list(range(1, 20)), max_new_tokens=2)
    eng.submit([1, 2, 3], max_new_tokens=2)
    seng.run_sync()                    # compile warmup, as in run_trace
    eng.stats = EngineStats(n_slots=eng.n_slots)

    t0_trace = clock.t
    streams = {}
    for i, item in enumerate(trace):
        arrival, prompt, gen = item[0], item[1], item[2]
        _, streams[i] = seng.submit_stream(
            prompt, max_new_tokens=gen, sampling=SamplingParams(seed=i),
            arrival=t0_trace + arrival,
            priority=item[3] if len(item) > 3 else 0)
    # the clock charges device work at DISPATCH (that is when the
    # program is enqueued); reconcile-only iterations are free — they
    # overlap the next tick's compute
    while seng.has_work:
        d0 = eng.stats.packed_decode_tokens
        p0 = eng.stats.packed_prefill_tokens
        kind = seng.step()
        if kind == "packed":
            clock.t += packed_step_flops(
                cfg,
                decode_tokens=eng.stats.packed_decode_tokens - d0,
                prefill_tokens=eng.stats.packed_prefill_tokens - p0,
                m_decode=MAX_CACHE,
                m_prefill=PREFILL_LEN) / costs["decode_flops"]
        elif kind == "decode":
            clock.t += costs["decode"]
        elif kind == "idle" and eng._pending:
            clock.t += max(0.0, eng.next_arrival() - eng.now())
    streamed = {i: streams[i].drain() for i in range(len(trace))}
    finished = {i: streams[i].finished for i in range(len(trace))}
    s = eng.stats.summary()
    return {
        "token_match": all(streamed[i] == sync_toks[i]
                           for i in range(len(trace))),
        "all_finished": all(f is not None for f in finished.values()),
        "tokens_streamed": s["tokens_streamed"],
        "packed_ticks": s["packed_ticks"],
        "decode_steps": s["decode_steps"],
        "ticks_idle": s["ticks_idle"],
    }


def run_stream_wall(trace, *, overlap: bool) -> dict:
    """Wall-clock streaming measurement: the SAME trace on real time
    (arrivals in seconds), TTFT/ITL percentiles in wall seconds, and
    the host-overhead fraction — host bookkeeping seconds over the
    loop's non-idle wall seconds, the number double-buffering exists to
    shrink (docs/streaming.md defines the measurement model).  Run with
    overlap on and off for the A/B the EXPERIMENTS entry reports."""
    import numpy as np
    from repro.serving import EngineStats, SamplingParams, StreamingEngine

    eng, _, cfg = build_engine("packed", wall=True)
    seng = StreamingEngine(eng, overlap=overlap)
    eng.submit(list(range(1, 20)), max_new_tokens=2)
    eng.submit([1, 2, 3], max_new_tokens=2)
    seng.run_sync()                    # compile warmup, unmeasured
    eng.stats = EngineStats(n_slots=eng.n_slots)

    t0 = eng.now()
    streams = {}
    for i, item in enumerate(trace):
        arrival, prompt, gen = item[0], item[1], item[2]
        _, streams[i] = seng.submit_stream(
            prompt, max_new_tokens=gen, sampling=SamplingParams(seed=i),
            arrival=t0 + arrival)
    w0 = time.perf_counter()
    seng.run_sync()
    wall_s = time.perf_counter() - w0
    itl = [dt for ds in seng.itl_samples().values() for dt in ds]
    s = eng.stats.summary()
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0
    return {
        "overlap": overlap,
        "requests": len(trace),
        "wall_s": wall_s,
        "decode_tokens_per_s": (eng.stats.generated_tokens / wall_s
                                if wall_s > 0 else 0.0),
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "itl_p50_s": pct(itl, 50),
        "itl_p99_s": pct(itl, 99),
        "host_overhead_fraction": s["host_overhead_fraction"],
        "ticks": s["packed_ticks"] + s["decode_steps"],
        "ticks_idle": s["ticks_idle"],
        "tokens_streamed": s["tokens_streamed"],
    }


def packed_cache_sized_concats() -> int:
    """Structural proof that the packed program never materializes a
    cache-sized concatenate: walk the traced jaxpr (same technique as
    the decode microbench) and count concatenate eqns whose output
    carries >= MAX_CACHE elements in any dim.  Walks the PAGED packed
    program — the production default — so the page-indirection gathers
    are covered by the gate too."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models import transformer as T
    from repro.runtime.paging import make_paged_layout
    from repro.runtime.serve import (ServeHParams, make_kv_cache,
                                     make_layout, make_packed_step)

    cfg = bench_config()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    hp = ServeHParams(decode_mode="exact", ssm_chunk=8)
    base = make_layout(cfg, mesh, N_SLOTS, MAX_CACHE, hp, PREFILL_LEN)
    paging = make_paged_layout(base, page_tokens=16, n_pages=None,
                               n_slots=N_SLOTS)
    step, lay, _, _ = make_packed_step(
        cfg, mesh, params, batch=N_SLOTS, cap=MAX_CACHE,
        prefill_len=PREFILL_LEN, token_budget=TOKEN_BUDGET, hp=hp,
        paging=paging)
    kv = make_kv_cache(cfg, mesh, lay, N_SLOTS, hp, paging=paging)
    tb = TOKEN_BUDGET
    args = (params, kv.storage, jnp.zeros(tb, jnp.int32),
            jnp.full(tb, -1, jnp.int32), jnp.full(tb, -1, jnp.int32),
            jnp.full(tb, -1, jnp.int32), jnp.zeros(tb, jnp.int32),
            jnp.asarray(kv.page_map(N_SLOTS)),
            jnp.asarray(kv.state_map(N_SLOTS)))

    def walk(jx):
        n = 0
        for e in jx.eqns:
            if (e.primitive.name == "concatenate"
                    and any(d >= MAX_CACHE
                            for d in e.outvars[0].aval.shape)):
                n += 1
            for sub in e.params.values():
                subs = sub if isinstance(sub, (list, tuple)) else [sub]
                n += sum(walk(s.jaxpr) for s in subs
                         if hasattr(s, "jaxpr"))
        return n
    return walk(jax.make_jaxpr(step)(*args).jaxpr)


def run_all() -> dict:
    """All traces through every relevant engine; the BENCH_engine.json
    payload, including the structural gates compare.py enforces."""
    import jax

    cfg = bench_config()
    costs = logical_costs(cfg)
    # main trace: generation-dominated serving at moderate load (chat
    # regime — decode work ≫ prefill work, generation lengths highly
    # variable, arrivals near the service rate) — the regime users
    # feel, where head-of-line blocking shows.
    main_trace = make_trace(cfg, n_requests=24, arrival_gap=30.0,
                            plen_range=(8, 33), gen_range=(8, 65), seed=0)
    # short-prompt trace: where pad-to-prefill_len waste is largest
    short_trace = make_trace(cfg, n_requests=16, arrival_gap=2.0,
                             plen_range=(4, 9), gen_range=(8, 25), seed=1)
    # saturated trace: arrivals far above the service rate, queue
    # always deep — the regime where PR-4's FLOP clock recorded gang
    # flushes out-amortizing per-row chunk calls; token packing is the
    # answer, and this trace gates it.
    sat_trace = make_trace(cfg, n_requests=24, arrival_gap=0.5,
                           plen_range=(8, 33), gen_range=(8, 33), seed=2)

    res, toks = {}, {}
    for trace_name, trace, modes in (
            ("main", main_trace, ("packed", "chunked", "padded", "gang")),
            ("short", short_trace, ("packed", "chunked", "padded")),
            ("saturated", sat_trace, ("packed", "chunked", "gang"))):
        res[trace_name], toks[trace_name] = {}, {}
        for m in modes:
            res[trace_name][m], toks[trace_name][m] = run_trace(
                m, trace, costs)

    # shared-prefix (system-prompt) trace: identical trace through the
    # packed engine with prefix reuse ON vs OFF — the tokens must match
    # exactly and ON must prefill strictly fewer prompt tokens
    prefix_trace = make_prefix_trace(
        cfg, n_requests=12, arrival_gap=120.0, prefix_len=24,
        suffix_range=(4, 9), gen_range=(8, 17), seed=3)
    res["prefix"], toks["prefix"] = {}, {}
    for name, on in (("prefix_on", True), ("prefix_off", False)):
        res["prefix"][name], toks["prefix"][name] = run_trace(
            "packed", prefix_trace, costs, prefix_cache=on)

    # overload (preemption) trace: identical page-starved trace with the
    # host offload tier ON vs OFF — spill/restore must not change a
    # token, and the interactive class's TTFT must not get worse (the
    # whole point of preempting background work).  prefix reuse is off
    # so page accounting is exact in both runs.
    overload_trace = make_overload_trace(cfg, seed=4)
    res["overload"], toks["overload"] = {}, {}
    for name, on in (("preempt_on", True), ("preempt_off", False)):
        res["overload"][name], toks["overload"][name] = run_trace(
            "packed", overload_trace, costs, prefix_cache=False,
            offload=on, n_pages=14)

    # chaos soak: the same overload trace under seeded all-kinds fault
    # injection, three seeds — surviving requests must emit the clean
    # run's exact tokens and the drained engine must audit leak-free
    res["chaos"] = {}
    for seed in (0, 1, 2):
        res["chaos"][f"seed{seed}"] = run_chaos(
            overload_trace, toks["overload"]["preempt_on"], seed=seed)

    # degraded-availability: a scheduled shard_loss kills the bench
    # mesh's only sequence shard mid-decode — the window must serve
    # finite tokens from the Segment-Means replicas and recovery must
    # restore token identity with the clean run
    deg_trace = make_trace(cfg, n_requests=10, arrival_gap=2.0,
                           plen_range=(8, 33), gen_range=(8, 25),
                           seed=6)
    res["degraded"] = run_degraded(deg_trace)

    # streaming: token identity vs the sync packed run on the identical
    # main trace (logical clock), then the wall-clock load sweep —
    # offered load rises low -> high; TTFT/ITL tails and the idle-tick
    # count locate the saturation knee, host_overhead_fraction is the
    # overlap-efficiency number the compare gate bounds
    res["stream"] = {"match": run_stream_match(
        main_trace, toks["main"]["packed"], costs)}
    res["stream"]["wall"] = {}
    for rate_name, gap in (("low", 0.10), ("mid", 0.02),
                           ("high", 0.004)):
        wtrace = make_trace(cfg, n_requests=10, arrival_gap=gap,
                            plen_range=(8, 33), gen_range=(8, 25),
                            seed=5)
        res["stream"]["wall"][rate_name] = {
            "overlap_on": run_stream_wall(wtrace, overlap=True),
            "overlap_off": run_stream_wall(wtrace, overlap=False)}

    flops = {}
    for trace_name, trace in (("main", main_trace),
                              ("short", short_trace)):
        for m in ("packed", "chunked", "padded"):
            flops[f"{trace_name}_{m}"] = prefill_flops_per_request(
                cfg, [len(p) for _, p, _ in trace], m)
    # measured (not analytic) prefill work on the prefix trace: packed
    # pays one query per token it ACTUALLY prefills, so per-request
    # FLOPs scale down with the prefix tokens never laid down
    from .common import serve_step_flops
    per_tok = serve_step_flops(cfg, rows=1, nq_per_row=1, m=PREFILL_LEN)
    for name in ("prefix_on", "prefix_off"):
        flops[f"prefix_{name}"] = (
            per_tok * res["prefix"][name]["prefill_tokens"]
            / len(prefix_trace))

    n_concats = packed_cache_sized_concats()
    gates = {
        # chunked prefill must cost fewer FLOPs per request AND no
        # worse median TTFT than the padded baseline on short prompts
        "short_prefill_flops_lower": (flops["short_chunked"]
                                      < flops["short_padded"]),
        "short_ttft_no_worse": (
            res["short"]["chunked"]["ttft_p50_steps"]
            <= res["short"]["padded"]["ttft_p50_steps"] + 1e-9),
        # chunked beats the padded-flush admission it replaces
        "chunked_vs_padded_ttft_no_worse": (
            res["main"]["chunked"]["ttft_p50_steps"]
            <= res["main"]["padded"]["ttft_p50_steps"] + 1e-9),
        # continuous batching vs static: TTFT is the classic win
        "continuous_vs_gang_ttft_speedup": (
            res["main"]["gang"]["ttft_p50_steps"]
            / max(res["main"]["chunked"]["ttft_p50_steps"], 1e-9)),
        "continuous_vs_gang_speedup": (
            res["main"]["chunked"]["requests_per_ksteps"]
            / res["main"]["gang"]["requests_per_ksteps"]),
        # ---- packed structural gates ---------------------------------
        # kernel-match analog: packed serving is token-identical to the
        # chunked oracle on the identical main trace
        "packed_token_match": all(
            toks["main"]["packed"][i] == toks["main"]["chunked"][i]
            for i in range(len(main_trace))),
        # the packed program materializes no cache-sized concatenate
        "packed_concat_free": n_concats == 0,
        "packed_cache_sized_concats": n_concats,
        # packing may not regress the moderate-load regime it inherits
        "packed_vs_chunked_no_regression": (
            res["main"]["packed"]["requests_per_ksteps"]
            >= 0.999 * res["main"]["chunked"]["requests_per_ksteps"]),
        # THE saturation claim: packed logical throughput >= gang while
        # TTFT p50 <= chunked — per-tick cost now scales with real
        # tokens, so packing out-amortizes the gang flush too
        "packed_vs_gang_saturated": (
            res["saturated"]["packed"]["requests_per_ksteps"]
            >= res["saturated"]["gang"]["requests_per_ksteps"]),
        "packed_ttft_no_worse_saturated": (
            res["saturated"]["packed"]["ttft_p50_steps"]
            <= res["saturated"]["chunked"]["ttft_p50_steps"] + 1e-9),
        "packed_vs_gang_saturated_speedup": (
            res["saturated"]["packed"]["requests_per_ksteps"]
            / max(res["saturated"]["gang"]["requests_per_ksteps"],
                  1e-9)),
        # ---- prefix-reuse gates --------------------------------------
        # COW sharing must not change a single token ...
        "prefix_token_match": all(
            toks["prefix"]["prefix_on"][i] == toks["prefix"]["prefix_off"][i]
            for i in range(len(prefix_trace))),
        # ... while strictly reducing the prompt tokens prefilled (the
        # saved fraction of the OFF run's prefill work)
        "prefix_reuse_savings": (
            (res["prefix"]["prefix_off"]["prefill_tokens"]
             - res["prefix"]["prefix_on"]["prefill_tokens"])
            / max(res["prefix"]["prefix_off"]["prefill_tokens"], 1)),
        "prefix_hits": res["prefix"]["prefix_on"]["prefix_hits"],
        "prefix_ttft_no_worse": (
            res["prefix"]["prefix_on"]["ttft_p50_steps"]
            <= res["prefix"]["prefix_off"]["ttft_p50_steps"] + 1e-9),
        # ---- preemption gates ----------------------------------------
        # spill -> host store -> restore must not change a single token
        # vs the same page-starved trace served without preemption ...
        "preempt_token_match": all(
            toks["overload"]["preempt_on"][i]
            == toks["overload"]["preempt_off"][i]
            for i in range(len(overload_trace))),
        # ... the overload trace must actually exercise the tier ...
        "preempt_fired": (
            res["overload"]["preempt_on"]["preemptions"] > 0
            and res["overload"]["preempt_on"]["restore_hits"] > 0),
        # ... and the interactive class (priority 1) must reach its
        # first token no later than when it has to queue behind
        # background decodes for free pages
        "preempt_ttft_no_worse": (
            res["overload"]["preempt_on"]["ttft_p50_by_class"]["1"]
            <= res["overload"]["preempt_off"]["ttft_p50_by_class"]["1"]
            + 1e-9),
        "preempt_interactive_ttft_speedup": (
            res["overload"]["preempt_off"]["ttft_p50_by_class"]["1"]
            / max(res["overload"]["preempt_on"]["ttft_p50_by_class"]["1"],
                  1e-9)),
        # ---- chaos-soak gates ----------------------------------------
        # every request a faulted engine completed is token-identical
        # to the clean run, on every seed ...
        "chaos_token_match": all(
            c["token_match"] and c["accounted"]
            for c in res["chaos"].values()),
        # ... the drained engine leaks nothing (pages, state rows,
        # store bytes, slots) on every seed ...
        "chaos_zero_leak": all(
            c["zero_leak"] for c in res["chaos"].values()),
        # ... and each seed actually injected faults AND completed
        # requests (an empty soak proves nothing)
        "chaos_faults_fired": all(
            c["faults_injected"] > 0 and c["completed"] > 0
            for c in res["chaos"].values()),
        # ---- degraded-mesh gates -------------------------------------
        # every stream crossing the shard-loss window still closed with
        # exactly its requested (finite) token count ...
        "degraded_streams_finite": res["degraded"]["streams_finite"],
        # ... the recovered engine audits clean ...
        "degraded_zero_leak": res["degraded"]["zero_leak"],
        # ... and after the re-prefill recovery every request finished
        # token-identical to the clean run, with the degraded window
        # actually observed (shard_lost/degraded_ticks >= 1)
        "degraded_recovery_token_match": (
            res["degraded"]["recovery_token_match"]),
        # ---- streaming gates -----------------------------------------
        # the overlapped double-buffered loop must deliver EXACTLY the
        # synchronous engine's tokens on the identical trace, and every
        # stream must close with a finish reason
        "stream_token_match": (res["stream"]["match"]["token_match"]
                               and res["stream"]["match"]["all_finished"]),
        "stream_overlap_ran": res["stream"]["match"]["packed_ticks"] > 0,
        # host bookkeeping share of the wall loop, worst overlap-on run
        # — a generous ceiling (the loop must be device-bound, not
        # host-bound; exact values vary with CI hardware)
        "host_overhead_fraction": max(
            w["overlap_on"]["host_overhead_fraction"]
            for w in res["stream"]["wall"].values()),
        "host_overhead_ok": all(
            0.0 <= w["overlap_on"]["host_overhead_fraction"] < 0.9
            for w in res["stream"]["wall"].values()),
    }
    return {
        "bench": "engine_throughput",
        "platform": jax.default_backend(),
        "config": {"n_slots": N_SLOTS, "prefill_len": PREFILL_LEN,
                   "max_cache": MAX_CACHE, "chunk_len": CHUNK_LEN,
                   "decode_per_prefill": DECODE_PER_PREFILL,
                   "token_budget": TOKEN_BUDGET,
                   "n_layers": cfg.n_layers, "d_model": cfg.d_model},
        "logical_costs": {k: v for k, v in costs.items()
                          if k != "decode_flops"},
        "traces": res,
        "prefill_flops_per_request": flops,
        "gates": gates,
    }


def main(report):
    payload = run_all()
    res, flops = payload["traces"], payload["prefill_flops_per_request"]
    for name in ("packed", "chunked", "padded", "gang"):
        s = res["main"][name]
        report(f"engine/{name}/requests_per_ksteps", 0.0,
               f"{s['requests_per_ksteps']:.1f}")
        report(f"engine/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f} (p90 {s['ttft_p90_steps']:.1f})")
        report(f"engine/{name}/occupancy", 0.0, f"{s['occupancy']:.2f}")
        report(f"engine/{name}/wall_ms", s["wall_decode_ms"] * 1e3,
               f"decode {s['wall_decode_ms']:.1f}ms "
               f"prefill {s['wall_prefill_ms']:.1f}ms "
               f"packed {s['wall_packed_ms']:.1f}ms")
    for name in ("packed", "chunked", "gang"):
        s = res["saturated"][name]
        report(f"engine/saturated/{name}/requests_per_ksteps", 0.0,
               f"{s['requests_per_ksteps']:.1f}")
        report(f"engine/saturated/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f}")
    for name in ("packed", "chunked", "padded"):
        s = res["short"][name]
        report(f"engine/short/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f}")
        report(f"engine/short/{name}/prefill_mflops_per_req", 0.0,
               f"{flops['short_' + name] / 1e6:.2f}")
    for name in ("prefix_on", "prefix_off"):
        s = res["prefix"][name]
        report(f"engine/prefix/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f}")
        report(f"engine/prefix/{name}/prefill_tokens", 0.0,
               f"{s['prefill_tokens']} (hits {s['prefix_hits']}, "
               f"saved {s['prefix_tokens_saved']})")
        report(f"engine/prefix/{name}/prefill_mflops_per_req", 0.0,
               f"{flops['prefix_' + name] / 1e6:.2f}")
    for name, c in res["chaos"].items():
        report(f"engine/chaos/{name}", 0.0,
               f"completed {c['completed']} failed {c['failed']} "
               f"faults {c['faults_injected']} "
               f"(restarts {c['restarts']}, quarantined "
               f"{c['quarantined']}) token_match={c['token_match']} "
               f"zero_leak={c['zero_leak']}")
    for name in ("preempt_on", "preempt_off"):
        s = res["overload"][name]
        report(f"engine/overload/{name}/requests_per_ksteps", 0.0,
               f"{s['requests_per_ksteps']:.1f}")
        report(f"engine/overload/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f} (p99 {s['ttft_p99_steps']:.1f})")
        report(f"engine/overload/{name}/interactive_ttft_p50", 0.0,
               f"{s['ttft_p50_by_class'].get('1', 0.0):.1f}")
        report(f"engine/overload/{name}/preemptions", 0.0,
               f"{s['preemptions']} (spilled {s['spilled_pages']} pages, "
               f"{s['restore_hits']} restores)")
    d = res["degraded"]
    report("engine/degraded/shard_loss", 0.0,
           f"shard_lost {d['shard_lost']} degraded_ticks "
           f"{d['degraded_ticks']} restarts {d['restarts']} "
           f"replica_captures {d['replica_captures']} "
           f"streams_finite={d['streams_finite']} "
           f"zero_leak={d['zero_leak']} "
           f"recovery_token_match={d['recovery_token_match']}")
    m = res["stream"]["match"]
    report("engine/stream/token_match", 0.0,
           f"{m['token_match']} ({m['tokens_streamed']} streamed over "
           f"{m['packed_ticks']} packed + {m['decode_steps']} decode "
           "ticks)")
    for rate_name, w in res["stream"]["wall"].items():
        for key in ("overlap_on", "overlap_off"):
            s = w[key]
            report(f"engine/stream/{rate_name}/{key}", s["wall_s"] * 1e6,
                   f"ttft p50 {s['ttft_p50_s'] * 1e3:.1f}ms "
                   f"p99 {s['ttft_p99_s'] * 1e3:.1f}ms, "
                   f"itl p50 {s['itl_p50_s'] * 1e3:.1f}ms "
                   f"p99 {s['itl_p99_s'] * 1e3:.1f}ms, "
                   f"{s['decode_tokens_per_s']:.0f} tok/s, "
                   f"host {100 * s['host_overhead_fraction']:.1f}% "
                   f"({s['ticks']} ticks, {s['ticks_idle']} idle)")
    g = payload["gates"]
    for gate in ("short_prefill_flops_lower", "short_ttft_no_worse",
                 "chunked_vs_padded_ttft_no_worse", "packed_token_match",
                 "packed_concat_free", "packed_vs_chunked_no_regression",
                 "packed_vs_gang_saturated",
                 "packed_ttft_no_worse_saturated", "prefix_token_match",
                 "prefix_ttft_no_worse", "preempt_token_match",
                 "preempt_fired", "preempt_ttft_no_worse",
                 "chaos_token_match", "chaos_zero_leak",
                 "chaos_faults_fired", "degraded_streams_finite",
                 "degraded_zero_leak", "degraded_recovery_token_match",
                 "stream_token_match",
                 "stream_overlap_ran", "host_overhead_ok"):
        report(f"engine/gate/{gate}", 0.0, str(g[gate]))
    report("engine/stream/host_overhead_fraction", 0.0,
           f"{100 * g['host_overhead_fraction']:.1f}% (worst overlap-on "
           "run)")
    report("engine/preempt_interactive_ttft_speedup", 0.0,
           f"x{g['preempt_interactive_ttft_speedup']:.2f}")
    report("engine/prefix_reuse_savings", 0.0,
           f"{100 * g['prefix_reuse_savings']:.1f}% of prefill tokens "
           f"({g['prefix_hits']} hits)")
    report("engine/continuous_vs_static_ttft_speedup", 0.0,
           f"x{g['continuous_vs_gang_ttft_speedup']:.2f}")
    report("engine/continuous_vs_static_speedup", 0.0,
           f"x{g['continuous_vs_gang_speedup']:.2f}")
    report("engine/packed_vs_gang_saturated_speedup", 0.0,
           f"x{g['packed_vs_gang_saturated_speedup']:.2f}")
    return payload


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="where to write the engine-bench artifact")
    args = ap.parse_args()

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    payload = main(_report)
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json}")
    g = payload["gates"]
    if not (g["short_prefill_flops_lower"] and g["short_ttft_no_worse"]
            and g["chunked_vs_padded_ttft_no_worse"]
            and g["packed_token_match"] and g["packed_concat_free"]
            and g["packed_vs_chunked_no_regression"]
            and g["packed_vs_gang_saturated"]
            and g["packed_ttft_no_worse_saturated"]
            and g["prefix_token_match"] and g["prefix_ttft_no_worse"]
            and g["prefix_reuse_savings"] > 0
            and g["preempt_token_match"] and g["preempt_fired"]
            and g["preempt_ttft_no_worse"]
            and g["chaos_token_match"] and g["chaos_zero_leak"]
            and g["chaos_faults_fired"]
            and g["degraded_streams_finite"] and g["degraded_zero_leak"]
            and g["degraded_recovery_token_match"]
            and g["stream_token_match"]
            and g["stream_overlap_ran"] and g["host_overhead_ok"]):
        sys.exit(1)

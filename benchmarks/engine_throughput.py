"""Serving-engine benchmark: chunked prefill vs padded flushes vs
static batching, on staggered-arrival traces.

Replays identical Poisson traces through ServingEngine instances that
differ only in admission policy:

  * chunked — the system: FIFO admission into any freed slot, prompts
              prefilled ``chunk_len`` tokens at a time, chunk steps
              interleaved with decodes
  * padded  — PR-2 continuous batching: one monolithic right-padded
              prefill flush per admission
  * gang    — classic static batching (admit into an empty pool only,
              drain completely): the head-of-line-blocking baseline

To keep the comparison deterministic on noisy shared CPUs — and
gateable in CI (``benchmarks/compare.py``) — the engines run on a
*logical* clock whose step costs come from the ANALYTIC FLOP model in
``benchmarks/common.py``: one decode step costs 1 unit; a chunk step
and a padded flush cost their FLOP multiple of a decode step.  Every
logical metric (requests per kstep, TTFT in steps, prefill FLOPs per
request) is a pure function of the code + trace seed.  Measured
wall-clock per step kind is reported alongside for the wall-time
conversions, but nothing gated depends on it.

Run standalone (writes the ``BENCH_engine.json`` artifact)::

    PYTHONPATH=src python -m benchmarks.engine_throughput \
        --json BENCH_engine.json

or via the harness (``python -m benchmarks.run --only engine``).
"""
from __future__ import annotations

import time

N_SLOTS, PREFILL_LEN, MAX_CACHE = 4, 32, 96
CHUNK_LEN, DECODE_PER_PREFILL = 8, 2


class StepClock:
    """Logical clock in decode-step units, advanced by the drive loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def bench_config():
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="bench-dense", arch_type="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)


def logical_costs(cfg) -> dict:
    """Analytic per-step costs in decode-step units (deterministic)."""
    from .common import serve_step_flops
    decode = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=1,
                              m=MAX_CACHE, lm_head=True)
    chunk = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=CHUNK_LEN,
                             m=PREFILL_LEN)
    flush = serve_step_flops(cfg, rows=N_SLOTS, nq_per_row=PREFILL_LEN,
                             m=PREFILL_LEN, lm_head=True)
    return {"decode": 1.0, "chunk": chunk / decode,
            "padded_flush": flush / decode, "decode_flops": decode}


def prefill_flops_per_request(cfg, plens, mode: str) -> float:
    """Mean per-request prefill FLOPs over a trace's prompt lengths:
    chunked pays ceil(len/chunk) chunks of chunk_len queries against
    the prefill region; padded always pays the full pad-to-length
    forward."""
    from .common import serve_step_flops
    total = 0.0
    for plen in plens:
        if mode == "chunked":
            n_chunks = -(-plen // CHUNK_LEN)
            total += n_chunks * serve_step_flops(
                cfg, rows=1, nq_per_row=CHUNK_LEN, m=PREFILL_LEN)
        else:
            total += serve_step_flops(cfg, rows=1,
                                      nq_per_row=PREFILL_LEN,
                                      m=PREFILL_LEN, lm_head=True)
    return total / max(1, len(plens))


def build_engine(mode: str):
    import jax
    from repro.models import transformer as T
    from repro.runtime.serve import ServeHParams
    from repro.serving import ServingEngine

    cfg = bench_config()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    clock = StepClock()
    eng = ServingEngine(
        cfg, mesh, params, n_slots=N_SLOTS, prefill_len=PREFILL_LEN,
        max_cache=MAX_CACHE,
        hp=ServeHParams(decode_mode="exact", ssm_chunk=8),
        decode_per_prefill=DECODE_PER_PREFILL,
        chunk_len=CHUNK_LEN,
        prefill_mode="padded" if mode == "padded" else "chunked",
        gang=(mode == "gang"), clock=clock)
    return eng, clock, cfg


def make_trace(cfg, *, n_requests, arrival_gap, plen_range, gen_range,
               seed=0):
    """Shared deterministic Poisson trace: [(arrival, prompt, gen)]."""
    import numpy as np
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(arrival_gap, size=n_requests))
    out = []
    for i in range(n_requests):
        plen = int(rng.integers(*plen_range))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        out.append((float(arrivals[i]), prompt,
                    int(rng.integers(*gen_range))))
    return out


def run_trace(mode: str, trace, costs) -> dict:
    """Drive one engine over a trace on the analytic logical clock.
    Returns logical metrics plus measured wall ms per step kind."""
    import numpy as np
    from repro.serving import EngineStats, SamplingParams

    eng, clock, cfg = build_engine(mode)
    # compile warmup outside the measured window (one multi-chunk
    # prompt + one short, through eviction)
    eng.submit(list(range(1, 20)), max_new_tokens=2)
    eng.submit([1, 2, 3], max_new_tokens=2)
    eng.run()
    warmed = len(eng.results())
    eng.stats = EngineStats(n_slots=eng.n_slots)

    t0_trace = clock.t
    for i, (arrival, prompt, gen) in enumerate(trace):
        eng.submit(prompt, max_new_tokens=gen,
                   sampling=SamplingParams(seed=i),
                   arrival=t0_trace + arrival)

    cost = {"decode": costs["decode"],
            "prefill": (costs["chunk"] if mode != "padded"
                        else costs["padded_flush"])}
    wall = {"decode": [], "prefill": []}
    while eng._sched.has_work or eng._pending:
        w0 = time.perf_counter()
        kind = eng.step()
        if kind in cost:
            wall[kind].append(time.perf_counter() - w0)
            clock.t += cost[kind]
        else:                               # idle: jump to next arrival
            clock.t += max(0.0, eng.next_arrival() - eng.now())
    steps = clock.t - t0_trace
    assert len(eng.results()) == len(trace) + warmed

    s = eng.stats.summary()
    med = (lambda xs: 1e3 * float(np.median(xs)) if xs else 0.0)
    return {
        "requests_per_ksteps": 1e3 * len(trace) / steps,
        "ttft_p50_steps": s["ttft_p50_s"],   # logical-clock units
        "ttft_p90_steps": s["ttft_p90_s"],
        "ttft_max_steps": s["ttft_max_s"],
        "occupancy": s["occupancy"],
        "prefills": s["prefills"],
        "prefill_chunks": s["prefill_chunks"],
        "prefill_tokens": s["prefill_tokens"],
        "decode_steps": s["decode_steps"],
        "elapsed_steps": steps,
        "wall_decode_ms": med(wall["decode"]),
        "wall_prefill_ms": med(wall["prefill"]),
    }


def run_all() -> dict:
    """Both traces through every relevant engine; the BENCH_engine.json
    payload, including the structural gates compare.py enforces."""
    import jax

    cfg = bench_config()
    costs = logical_costs(cfg)
    # main trace: generation-dominated serving at moderate load (chat
    # regime — decode work ≫ prefill work, generation lengths highly
    # variable, arrivals near the service rate).  Under heavy
    # saturation static batching amortizes prefill across a whole gang
    # and wins raw FLOP throughput (the docs discuss it); the serving
    # regime users feel is this one, where head-of-line blocking shows.
    main_trace = make_trace(cfg, n_requests=24, arrival_gap=30.0,
                            plen_range=(8, 33), gen_range=(8, 65), seed=0)
    # short-prompt trace: where pad-to-prefill_len waste is largest
    short_trace = make_trace(cfg, n_requests=16, arrival_gap=2.0,
                             plen_range=(4, 9), gen_range=(8, 25), seed=1)

    res = {
        "main": {m: run_trace(m, main_trace, costs)
                 for m in ("chunked", "padded", "gang")},
        "short": {m: run_trace(m, short_trace, costs)
                  for m in ("chunked", "padded")},
    }
    flops = {
        "main_chunked": prefill_flops_per_request(
            cfg, [len(p) for _, p, _ in main_trace], "chunked"),
        "main_padded": prefill_flops_per_request(
            cfg, [len(p) for _, p, _ in main_trace], "padded"),
        "short_chunked": prefill_flops_per_request(
            cfg, [len(p) for _, p, _ in short_trace], "chunked"),
        "short_padded": prefill_flops_per_request(
            cfg, [len(p) for _, p, _ in short_trace], "padded"),
    }
    gates = {
        # chunked prefill must cost fewer FLOPs per request AND no
        # worse median TTFT than the padded baseline on short prompts
        "short_prefill_flops_lower": (flops["short_chunked"]
                                      < flops["short_padded"]),
        "short_ttft_no_worse": (
            res["short"]["chunked"]["ttft_p50_steps"]
            <= res["short"]["padded"]["ttft_p50_steps"] + 1e-9),
        # chunked beats the padded-flush admission it replaces
        "chunked_vs_padded_ttft_no_worse": (
            res["main"]["chunked"]["ttft_p50_steps"]
            <= res["main"]["padded"]["ttft_p50_steps"] + 1e-9),
        # continuous batching vs static: TTFT is the classic win
        "continuous_vs_gang_ttft_speedup": (
            res["main"]["gang"]["ttft_p50_steps"]
            / max(res["main"]["chunked"]["ttft_p50_steps"], 1e-9)),
        "continuous_vs_gang_speedup": (
            res["main"]["chunked"]["requests_per_ksteps"]
            / res["main"]["gang"]["requests_per_ksteps"]),
    }
    return {
        "bench": "engine_throughput",
        "platform": jax.default_backend(),
        "config": {"n_slots": N_SLOTS, "prefill_len": PREFILL_LEN,
                   "max_cache": MAX_CACHE, "chunk_len": CHUNK_LEN,
                   "decode_per_prefill": DECODE_PER_PREFILL,
                   "n_layers": cfg.n_layers, "d_model": cfg.d_model},
        "logical_costs": {k: v for k, v in costs.items()
                          if k != "decode_flops"},
        "traces": res,
        "prefill_flops_per_request": flops,
        "gates": gates,
    }


def main(report):
    payload = run_all()
    res, flops = payload["traces"], payload["prefill_flops_per_request"]
    for name in ("chunked", "padded", "gang"):
        s = res["main"][name]
        report(f"engine/{name}/requests_per_ksteps", 0.0,
               f"{s['requests_per_ksteps']:.1f}")
        report(f"engine/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f} (p90 {s['ttft_p90_steps']:.1f})")
        report(f"engine/{name}/occupancy", 0.0, f"{s['occupancy']:.2f}")
        report(f"engine/{name}/wall_ms", s["wall_decode_ms"] * 1e3,
               f"decode {s['wall_decode_ms']:.1f}ms "
               f"prefill {s['wall_prefill_ms']:.1f}ms")
    for name in ("chunked", "padded"):
        s = res["short"][name]
        report(f"engine/short/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f}")
        report(f"engine/short/{name}/prefill_mflops_per_req", 0.0,
               f"{flops['short_' + name] / 1e6:.2f}")
    g = payload["gates"]
    report("engine/gate/short_prefill_flops_lower", 0.0,
           str(g["short_prefill_flops_lower"]))
    report("engine/gate/short_ttft_no_worse", 0.0,
           str(g["short_ttft_no_worse"]))
    report("engine/gate/chunked_vs_padded_ttft_no_worse", 0.0,
           str(g["chunked_vs_padded_ttft_no_worse"]))
    report("engine/continuous_vs_static_ttft_speedup", 0.0,
           f"x{g['continuous_vs_gang_ttft_speedup']:.2f}")
    report("engine/continuous_vs_static_speedup", 0.0,
           f"x{g['continuous_vs_gang_speedup']:.2f}")
    return payload


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="where to write the engine-bench artifact")
    args = ap.parse_args()

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    payload = main(_report)
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json}")
    g = payload["gates"]
    if not (g["short_prefill_flops_lower"] and g["short_ttft_no_worse"]
            and g["chunked_vs_padded_ttft_no_worse"]):
        sys.exit(1)

"""Continuous batching vs static batching on a staggered-arrival trace.

Replays the same Poisson trace through two ServingEngine instances that
differ only in admission policy:

  * continuous — FIFO admission into any freed slot, mid-flight
  * gang       — classic static batching: admit only into an empty
                 pool, drain it completely (head-of-line blocking)

To keep the comparison deterministic on noisy shared CPUs, the engines
run on a *logical* clock (the injectable ``clock=`` hook): one decode
step costs 1 unit, one prefill flush costs its measured wall-clock
multiple of a decode step, and idle time jumps to the next arrival.
Requests/s and TTFT are then converted back to wall time with the
measured decode-step latency, so the numbers are real — only the
scheduling comparison is noise-free.  Run standalone::

    PYTHONPATH=src python -m benchmarks.engine_throughput

or via the harness (``python -m benchmarks.run --only engine``).
"""
from __future__ import annotations

import time


class StepClock:
    """Logical clock in decode-step units, advanced by the drive loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def build_engine(gang: bool):
    import jax
    from repro.models.config import ModelConfig
    from repro.models import transformer as T
    from repro.runtime.serve import ServeHParams
    from repro.serving import ServingEngine

    cfg = ModelConfig(
        name="bench-dense", arch_type="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope",
        tie_embeddings=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = T.init(cfg, jax.random.PRNGKey(0))
    clock = StepClock()
    eng = ServingEngine(cfg, mesh, params, n_slots=4, prefill_len=32,
                        max_cache=96,
                        hp=ServeHParams(decode_mode="exact", ssm_chunk=8),
                        decode_per_prefill=2, gang=gang, clock=clock)
    return eng, clock, cfg


def calibrate(eng, clock) -> tuple:
    """Measure the wall cost of a decode step and a prefill flush on the
    compiled engine.  Returns (decode_s, prefill_over_decode_ratio)."""
    times = {"prefill": [], "decode": []}
    for i in range(4):                      # staggered: several prefills
        eng.submit([1 + i, 2, 3], max_new_tokens=6)
        while eng._sched.has_work:
            t0 = time.perf_counter()
            kind = eng.step()
            dt = time.perf_counter() - t0
            if kind in times:
                times[kind].append(dt)
            clock.t += 1.0
    times["decode"].sort()
    times["prefill"].sort()
    dec = times["decode"][len(times["decode"]) // 2]
    pre = times["prefill"][len(times["prefill"]) // 2]
    return dec, max(1.0, pre / dec)


def run_engine(gang: bool, *, n_requests=24, arrival_gap=2.0, seed=0):
    """Drive one engine over the shared trace.  ``arrival_gap`` is the
    mean Poisson gap in decode-step units (mean service is ~8 units per
    request on 4 slots, so a gap of 2 keeps a backlog — the regime
    where admission policy decides throughput)."""
    import numpy as np
    from repro.serving import EngineStats, SamplingParams

    eng, clock, cfg = build_engine(gang)
    decode_s, prefill_cost = calibrate(eng, clock)
    warmed = len(eng.results())
    eng.stats = EngineStats(n_slots=eng.n_slots)

    rng = np.random.default_rng(seed)
    arrivals = clock.t + np.cumsum(
        rng.exponential(arrival_gap, size=n_requests))
    for i in range(n_requests):
        plen = int(rng.integers(8, 33))
        eng.submit(rng.integers(1, cfg.vocab_size, size=plen),
                   max_new_tokens=int(rng.integers(8, 57)),
                   sampling=SamplingParams(seed=i),
                   arrival=float(arrivals[i]))

    t_start = clock.t
    while eng._sched.has_work or eng._pending:
        kind = eng.step()
        if kind == "decode":
            clock.t += 1.0
        elif kind == "prefill":
            clock.t += prefill_cost
        else:                               # idle: jump to next arrival
            # advance in the ENGINE's frame — next_arrival()/now() are
            # engine-relative, and the raw clock may have a nonzero
            # origin by the time the trace runs
            clock.t += max(0.0, eng.next_arrival() - eng.now())
    steps = clock.t - t_start
    assert len(eng.results()) == n_requests + warmed

    s = eng.stats.summary()
    return {
        "requests_per_ksteps": 1e3 * n_requests / steps,
        "requests_per_s": n_requests / (steps * decode_s),
        "ttft_p50_steps": s["ttft_p50_s"],   # logical-clock units
        "ttft_p90_steps": s["ttft_p90_s"],
        "ttft_p50_ms": 1e3 * s["ttft_p50_s"] * decode_s,
        "ttft_p90_ms": 1e3 * s["ttft_p90_s"] * decode_s,
        "occupancy": s["occupancy"],
        "decode_step_ms": 1e3 * decode_s,
        "prefill_cost_steps": prefill_cost,
    }


def main(report):
    cont = run_engine(gang=False)
    gang = run_engine(gang=True)
    # one shared wall conversion (min = least scheduler-noise estimate),
    # so the requests/s comparison reflects scheduling, not CPU jitter
    decode_s = min(cont["decode_step_ms"], gang["decode_step_ms"]) / 1e3
    for s in (cont, gang):
        scale = (s["decode_step_ms"] / 1e3) / decode_s
        s["requests_per_s"] *= scale
        s["ttft_p50_ms"] /= scale
        s["ttft_p90_ms"] /= scale
        s["decode_step_ms"] = 1e3 * decode_s
    for name, s in (("continuous", cont), ("static_gang", gang)):
        report(f"engine/{name}/requests_per_ksteps", 0.0,
               f"{s['requests_per_ksteps']:.1f}")
        report(f"engine/{name}/requests_per_s", 0.0,
               f"{s['requests_per_s']:.2f} (at {s['decode_step_ms']:.1f} "
               "ms/step)")
        report(f"engine/{name}/ttft_p50_steps", 0.0,
               f"{s['ttft_p50_steps']:.1f} ({s['ttft_p50_ms']:.0f} ms)")
        report(f"engine/{name}/ttft_p90_steps", 0.0,
               f"{s['ttft_p90_steps']:.1f} ({s['ttft_p90_ms']:.0f} ms)")
        report(f"engine/{name}/occupancy", 0.0, f"{s['occupancy']:.2f}")
    speedup = cont["requests_per_ksteps"] / gang["requests_per_ksteps"]
    report("engine/continuous_vs_static_speedup", 0.0, f"x{speedup:.2f}")


if __name__ == "__main__":
    import sys

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    main(_report)

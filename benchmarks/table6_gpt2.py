"""Paper Table VI — GPT-2, CR sweep 2..10 at P ∈ {2, 3}.

GFLOPs / comm columns are analytic over the real PRISM shapes (like the
paper's); the BPC-vs-CR accuracy trend is measured on a trained char-LM
by accuracy_vs_cr.py (CBT/enwik8/text8 are unavailable offline).
"""
from __future__ import annotations

from .common import GPT2_SMALL as S, model_flops, comm_elements, speedup

PAPER_PER_DEV = {  # (P, CR) -> paper GFLOPs/device
    (2, 2): 34.36, (2, 4): 33.30, (2, 6): 32.94, (2, 8): 32.77,
    (2, 10): 32.64,
    (3, 2): 24.01, (3, 4): 22.68, (3, 6): 22.24, (3, 8): 21.99,
    (3, 10): 21.86,
}


def rows():
    base = model_flops(S, "single", 1, 0)["per_device_gflops"]
    out = [{
        "strategy": "single", "P": 1, "CR": "-",
        "total_gflops": round(model_flops(S, "single", 1, 0)
                              ["total_gflops"], 2),
        "per_device_gflops": round(base, 2),
        "comp_speedup_pct": 0.0, "comm_speedup_pct": "-",
        "paper_per_dev": 65.71,
    }]
    for p in (2, 3):
        f = model_flops(S, "voltage", p, 0)
        out.append({
            "strategy": "voltage", "P": p, "CR": "-",
            "total_gflops": round(f["total_gflops"], 2),
            "per_device_gflops": round(f["per_device_gflops"], 2),
            "comp_speedup_pct": round(
                speedup(base, f["per_device_gflops"]), 2),
            "comm_speedup_pct": 0.0,
            "paper_per_dev": {2: 36.49, 3: 26.74}[p],
        })
    for p in (2, 3):
        for cr in range(2, 11):
            L = max(1, int(S.n // (cr * p)))          # Eq. 16
            f = model_flops(S, "prism", p, L)
            volt = comm_elements(S, "voltage", p, 0)
            ours = comm_elements(S, "prism", p, L)
            out.append({
                "strategy": "prism", "P": p, "CR": cr,
                "total_gflops": round(f["total_gflops"], 2),
                "per_device_gflops": round(f["per_device_gflops"], 2),
                "comp_speedup_pct": round(
                    speedup(base, f["per_device_gflops"]), 2),
                "comm_speedup_pct": round(speedup(volt, ours), 2),
                "paper_per_dev": PAPER_PER_DEV.get((p, cr), "-"),
            })
    return out


def main(report):
    for r in rows():
        name = f"table6/gpt2/{r['strategy']}-P{r['P']}-CR{r['CR']}"
        report(name, 0.0,
               f"/dev={r['per_device_gflops']}GF"
               f"(paper {r['paper_per_dev']}) "
               f"comp+{r['comp_speedup_pct']}% "
               f"comm+{r['comm_speedup_pct']}%")

"""Paper Table IV — ViT computation & communication efficiency.

Reproduces the structure and the paper's own operating points: P∈{2,3}
with the paper's PDPLC token counts, reporting total / per-device GFLOPs
(analytic model over the real PRISM shapes), computation speed-up vs the
single-device baseline, CR, and communication speed-up vs Voltage.
Accuracy columns are covered by the trained-model benchmark
(accuracy_vs_cr), since ImageNet/CIFAR are unavailable offline.
"""
from __future__ import annotations

from .common import VIT_B16 as S, model_flops, comm_elements, speedup


ROWS = [
    # (mode, P, PDPLC tokens L)
    ("single", 1, 0),
    ("voltage", 2, 0),
    ("voltage", 3, 0),
    ("prism", 2, 10),
    ("prism", 2, 20),
    ("prism", 2, 30),
    ("prism", 3, 20),
    ("prism", 3, 40),
    ("prism", 3, 60),
]

PAPER = {  # strategy -> paper's (total, /device) GFLOPs for reference
    ("single", 1, 0): (35.15, 35.15),
    ("voltage", 2, 0): (40.74, 20.37),
    ("voltage", 3, 0): (46.33, 15.44),
    ("prism", 2, 10): (35.07, 17.54),
    ("prism", 2, 20): (35.71, 17.86),
    ("prism", 2, 30): (36.35, 18.18),
    ("prism", 3, 20): (36.04, 12.01),
    ("prism", 3, 40): (37.89, 12.63),
    ("prism", 3, 60): (39.73, 13.24),
}


def rows():
    base = model_flops(S, "single", 1, 0)["per_device_gflops"]
    out = []
    for mode, p, pdplc in ROWS:
        # 'PDPLC Tokens' in the paper = tokens RECEIVED per device per
        # layer = (P-1)·L  ->  L = PDPLC/(P-1)
        L = pdplc // max(1, p - 1) if pdplc else 0
        f = model_flops(S, mode, p, L)
        volt = comm_elements(S, "voltage", p, 0)
        ours = comm_elements(S, mode, p, L)
        cr = (S.n / (L * p)) if L else float("nan")
        paper_t, paper_d = PAPER.get((mode, p, pdplc), (float("nan"),) * 2)
        out.append({
            "strategy": mode, "P": p, "PDPLC": pdplc,
            "total_gflops": round(f["total_gflops"], 2),
            "per_device_gflops": round(f["per_device_gflops"], 2),
            "comp_speedup_pct": round(
                speedup(base, f["per_device_gflops"]), 2),
            "CR": round(cr, 2) if L else "-",
            "comm_speedup_pct": round(speedup(volt, ours), 2) if p > 1
            else "-",
            "paper_total": paper_t, "paper_per_dev": paper_d,
        })
    return out


def main(report):
    for r in rows():
        name = f"table4/vit/{r['strategy']}-P{r['P']}-L{r['PDPLC']}"
        report(name, 0.0,
               f"GF={r['total_gflops']}(paper {r['paper_total']}) "
               f"/dev={r['per_device_gflops']}(paper {r['paper_per_dev']}) "
               f"comp+{r['comp_speedup_pct']}% comm+{r['comm_speedup_pct']}%")

"""Operator microbenchmarks (CPU wall-clock; the TPU path is validated
structurally via the dry-run, since Pallas interpret mode is a Python
emulator whose timing is meaningless).

``python -m benchmarks.microbench`` runs just the decode-attention
section and writes the ``BENCH_decode.json`` artifact the CI bench
smoke job uploads — the start of the decode-perf trajectory (see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations


def decode_attention_bench(report):
    """The serving hot path: per-token decode attention.

    Three checks, strongest first:

    * concat-free structural proof — walk the jaxprs of the old prism
      decode (`prism_decode_attention`) and the routed path
      (`decode_attention(backend='jnp')`) and count ``concatenate``
      ops producing cache-sized arrays: the old path allocates 3 per
      layer per token (k, v, g), the new path MUST have 0;
    * kernel correctness — the Pallas flash-decode kernel (interpret
      mode, i.e. the exact code a TPU compiles) against the jnp stats
      oracle;
    * measured wall-clock — old vs new, jnp vs jnp.  On CPU XLA fuses
      the concatenate into the consumer, so expect ~1x here; the
      number exists to start the trajectory for real-accelerator runs,
      where the per-step HBM allocation is the cost (EXPERIMENTS.md
      §Perf).

    Returns the BENCH_decode.json payload."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.decode_attention import (decode_stats_reference,
                                                flash_decode_stats)
    from repro.runtime.serve import decode_attention, prism_decode_attention
    from .common import timeit

    # -- structural: kernel (interpret) == jnp oracle, modest shape ----
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, m, mz, hq, hkv, hd = 2, 96, 8, 4, 2, 32
    q = jax.random.normal(ks[0], (b, 1, hq, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, m, hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, m, hkv, hd)) * 0.5
    kz = jax.random.normal(ks[3], (b, mz, hkv, hd)) * 0.5
    vz = jax.random.normal(ks[4], (b, mz, hkv, hd)) * 0.5
    pos = np.array([m - 1, m // 3])
    valid = jnp.asarray(np.arange(m)[None, :] <= pos[:, None])
    log_gz = jnp.full((b, mz), np.log(4.0), jnp.float32)
    scale = hd ** -0.5
    got = flash_decode_stats(q, k, v, valid, log_gz, kz, vz,
                             scale=scale, interpret=True)
    want = decode_stats_reference(q, k, v, valid, log_gz, kz, vz,
                                  scale=scale)
    err = max(float(jnp.max(jnp.abs(g - w))) for g, w in zip(got, want))
    ok = err < 1e-5
    report("micro/decode/kernel_vs_oracle", 0.0,
           f"interpret-mode max|Δ|={err:.2e} ({'OK' if ok else 'FAIL'})")

    # -- measured: concat-per-step vs two-pass stat merge --------------
    b, m, mz, hq, hkv, hd = 4, 2048, 64, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (b, 1, hq, hd))
    k = jax.random.normal(ks[1], (b, m, hkv, hd))
    v = jax.random.normal(ks[2], (b, m, hkv, hd))
    kz = jax.random.normal(ks[3], (b, mz, hkv, hd))
    vz = jax.random.normal(ks[4], (b, mz, hkv, hd))
    pos = np.full(b, m - 1)
    valid = jnp.asarray(np.arange(m)[None, :] <= pos[:, None])
    gz = jnp.full((b, mz), 16.0, jnp.float32)
    owner = jnp.ones((b,), bool)
    scale = hd ** -0.5

    def f_old_fn(q, k, v, valid, gz, kz, vz, owner):
        return prism_decode_attention(q, k, v, kz, vz, valid, gz,
                                      owner, (), scale)

    def f_new_fn(q, k, v, valid, gz, kz, vz, owner):
        return decode_attention(q, k, v, valid, (), scale, gz=gz,
                                kz=kz, vz=vz, owner=owner,
                                mode="prism", backend="jnp")

    def cache_sized_concats(fn, *args):
        """Count concatenate eqns whose output carries >= M columns —
        the per-step cache-sized HBM allocations the refactor removes."""
        def walk(jx):
            n = 0
            for e in jx.eqns:
                if (e.primitive.name == "concatenate"
                        and len(e.outvars[0].aval.shape) >= 2
                        and e.outvars[0].aval.shape[1] >= m):
                    n += 1
                for sub in e.params.values():
                    subs = sub if isinstance(sub, (list, tuple)) else [sub]
                    n += sum(walk(s.jaxpr) for s in subs
                             if hasattr(s, "jaxpr"))
            return n
        return walk(jax.make_jaxpr(fn)(*args).jaxpr)

    args = (q, k, v, valid, gz, kz, vz, owner)
    n_old = cache_sized_concats(f_old_fn, *args)
    n_new = cache_sized_concats(f_new_fn, *args)
    assert n_old > 0, "oracle lost its concat — bench is vacuous"
    assert n_new == 0, f"decode path still concatenates ({n_new}x)"
    report("micro/decode/cache_sized_concats", 0.0,
           f"per step: old={n_old} new={n_new} (must be 0)")

    f_old = jax.jit(f_old_fn)
    f_new = jax.jit(f_new_fn)
    t_old = timeit(lambda: f_old(*args).block_until_ready(), iters=30)
    t_new = timeit(lambda: f_new(*args).block_until_ready(), iters=30)
    report("micro/decode/prism_concat_step", t_old,
           f"M={m}+{mz} cols, {n_old} cache-sized concats per step")
    report("micro/decode/prism_twopass_step", t_new,
           f"concat-free; wall-clock x{t_old / t_new:.2f} "
           "(~1x on CPU: XLA fuses the concat; the win is HBM "
           "allocation on accelerators)")

    # exact path for the trajectory too (no concat in either, so this
    # tracks the stats-path overhead vs the dense oracle)
    from repro.runtime.serve import flash_decode_combine
    f_dense = jax.jit(lambda q, k, v, valid:
                      flash_decode_combine(q, k, v, valid, (), scale))
    f_stats = jax.jit(lambda q, k, v, valid:
                      decode_attention(q, k, v, valid, (), scale,
                                       backend="jnp"))
    t_dense = timeit(lambda: f_dense(q, k, v, valid).block_until_ready(),
                     iters=20)
    t_stats = timeit(lambda: f_stats(q, k, v, valid).block_until_ready(),
                     iters=20)
    report("micro/decode/exact_step", t_stats,
           f"vs dense oracle {t_dense:.1f}us")

    return {
        "bench": "decode_attention",
        "platform": jax.default_backend(),
        "shape": {"B": b, "M_local": m, "M_means": mz, "Hq": hq,
                  "Hkv": hkv, "hd": hd},
        "kernel_vs_oracle_max_abs_err": err,
        "kernel_vs_oracle_ok": bool(ok),
        "cache_sized_concats_per_step_old": n_old,
        "cache_sized_concats_per_step_new": n_new,
        "concat_free": n_new == 0,
        "prism_concat_us_per_step": t_old,
        "prism_twopass_us_per_step": t_new,
        "prism_concat_free_speedup": t_old / t_new,
        "exact_stats_us_per_step": t_stats,
        "exact_dense_oracle_us_per_step": t_dense,
    }


def main(report):
    import jax
    import jax.numpy as jnp
    from repro.core.attention import prism_attention
    from repro.core.segment_means import segment_means
    from .common import timeit

    key = jax.random.PRNGKey(0)

    # PRISM vs exact attention at the operating point where the compute
    # saving shows: N_p local + (P-1)L means vs full N columns.
    b, n, p, L, h, hd = 1, 2048, 4, 32, 8, 64
    n_p = n // p
    m = n_p + (p - 1) * L
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, n_p, h, hd))
    k_c = jax.random.normal(ks[1], (b, m, h, hd))
    v_c = jax.random.normal(ks[2], (b, m, h, hd))
    k_f = jax.random.normal(ks[3], (b, n, h, hd))
    v_f = jax.random.normal(ks[4], (b, n, h, hd))
    g = jnp.concatenate([jnp.ones(n_p), jnp.full(((p - 1) * L,), 16.0)])

    f_prism = jax.jit(lambda q, k, v, g: prism_attention(q, k, v, g=g))
    f_volt = jax.jit(lambda q, k, v: prism_attention(q, k, v))
    t_p = timeit(lambda: f_prism(q, k_c, v_c, g).block_until_ready(),
                 iters=10)
    t_v = timeit(lambda: f_volt(q, k_f, v_f).block_until_ready(),
                 iters=10)
    report("micro/attention/prism_device_view", t_p,
           f"M={m} cols")
    report("micro/attention/voltage_device_view", t_v,
           f"M={n} cols; prism speedup x{t_v / t_p:.2f}")

    x = jax.random.normal(ks[5], (8, 4096, 1024))
    f_sm = jax.jit(lambda x: segment_means(x, 32))
    t_sm = timeit(lambda: f_sm(x).block_until_ready(), iters=10)
    report("micro/segment_means/8x4096x1024->32", t_sm,
           f"{x.size * 4 / (t_sm / 1e6) / 1e9:.1f} GB/s read")

    decode_attention_bench(report)


if __name__ == "__main__":
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="where to write the decode-bench artifact")
    args = ap.parse_args()

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    payload = decode_attention_bench(_report)
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.json}")
    if not (payload["kernel_vs_oracle_ok"] and payload["concat_free"]):
        sys.exit(1)

"""Operator microbenchmarks (CPU wall-clock; the TPU path is validated
structurally via the dry-run, since Pallas interpret mode is a Python
emulator whose timing is meaningless)."""
from __future__ import annotations


def main(report):
    import jax
    import jax.numpy as jnp
    from repro.core.attention import prism_attention
    from repro.core.segment_means import segment_means
    from .common import timeit

    key = jax.random.PRNGKey(0)

    # PRISM vs exact attention at the operating point where the compute
    # saving shows: N_p local + (P-1)L means vs full N columns.
    b, n, p, L, h, hd = 1, 2048, 4, 32, 8, 64
    n_p = n // p
    m = n_p + (p - 1) * L
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (b, n_p, h, hd))
    k_c = jax.random.normal(ks[1], (b, m, h, hd))
    v_c = jax.random.normal(ks[2], (b, m, h, hd))
    k_f = jax.random.normal(ks[3], (b, n, h, hd))
    v_f = jax.random.normal(ks[4], (b, n, h, hd))
    g = jnp.concatenate([jnp.ones(n_p), jnp.full(((p - 1) * L,), 16.0)])

    f_prism = jax.jit(lambda q, k, v, g: prism_attention(q, k, v, g=g))
    f_volt = jax.jit(lambda q, k, v: prism_attention(q, k, v))
    t_p = timeit(lambda: f_prism(q, k_c, v_c, g).block_until_ready(),
                 iters=10)
    t_v = timeit(lambda: f_volt(q, k_f, v_f).block_until_ready(),
                 iters=10)
    report("micro/attention/prism_device_view", t_p,
           f"M={m} cols")
    report("micro/attention/voltage_device_view", t_v,
           f"M={n} cols; prism speedup x{t_v / t_p:.2f}")

    x = jax.random.normal(ks[5], (8, 4096, 1024))
    f_sm = jax.jit(lambda x: segment_means(x, 32))
    t_sm = timeit(lambda: f_sm(x).block_until_ready(), iters=10)
    report("micro/segment_means/8x4096x1024->32", t_sm,
           f"{x.size * 4 / (t_sm / 1e6) / 1e9:.1f} GB/s read")

"""Bench-regression gate: compare fresh benchmark artifacts against
the baselines committed at the repo root and FAIL on regression.

    PYTHONPATH=src python -m benchmarks.compare \
        --decode-baseline BENCH_decode.json \
        --decode-current  out/BENCH_decode.json \
        --engine-baseline BENCH_engine.json \
        --engine-current  out/BENCH_engine.json \
        --out out/BENCH_compare.json

Gates (exit 1 on any failure):

  * structural, from the decode microbench artifact — the Pallas
    flash-decode kernel must match its jnp oracle and the decode path
    must stay concatenate-free (the PR-3 win cannot silently regress);
  * structural, from the engine artifact — chunked prefill must keep
    costing fewer FLOPs per request and no worse TTFT than the padded
    baseline (the PR-4 win); the token-packed tick must stay
    token-identical to the chunked oracle (kernel-match), concatenate-
    free, and no slower than chunked on the main trace, and on the
    saturated trace must hold the PR-5 claim — logical throughput >=
    gang with TTFT p50 <= chunked; on the page-starved overload trace
    the host offload tier must stay token-identical with preemption ON
    vs OFF and must not worsen the interactive class's TTFT (PR-7);
    under seeded all-kinds fault injection (PR-8 chaos soak, 3 seeds)
    every completed request must be token-identical to the clean run
    and the drained engine must audit leak-free; the async streaming
    loop (PR-9) must stream token-identical output to the synchronous
    engine on the identical trace, and its wall-clock host-overhead
    fraction must stay under a coarse 0.9 ceiling (device-bound loop);
    on the degraded-mesh trace (PR-10) a scheduled shard loss must keep
    every stream finite through the Segment-Means substitution window,
    audit leak-free after recovery, and finish token-identical to the
    clean run;
  * throughput — the engine's logical-clock requests-per-kstep (packed
    and chunked, main trace) may not regress more than ``--tolerance``
    (default 20%) vs the committed baseline.  The logical clock runs
    on the analytic FLOP cost model (``benchmarks/common.py``), so
    this number is a deterministic function of the code and the gate
    is free of CI wall-clock noise.

Wall-clock fields are compared and reported in the output artifact but
never gated.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path):
    with open(path) as f:
        return json.load(f)


def compare(decode_base, decode_cur, engine_base, engine_cur,
            tolerance: float) -> dict:
    """Pure comparison — returns {'gates': [...], 'ok': bool, ...}."""
    gates = []

    def gate(name, ok, detail):
        gates.append({"gate": name, "ok": bool(ok), "detail": detail})

    # -- decode microbench: structural ---------------------------------
    gate("decode/kernel_vs_oracle",
         decode_cur.get("kernel_vs_oracle_ok", False),
         f"max|Δ|={decode_cur.get('kernel_vs_oracle_max_abs_err')}")
    gate("decode/concat_free", decode_cur.get("concat_free", False),
         f"cache-sized concats per step="
         f"{decode_cur.get('cache_sized_concats_per_step_new')}")

    # -- engine bench: structural --------------------------------------
    eg = engine_cur.get("gates", {})
    gate("engine/short_prefill_flops_lower",
         eg.get("short_prefill_flops_lower", False),
         str(engine_cur.get("prefill_flops_per_request", {})))
    gate("engine/short_ttft_no_worse",
         eg.get("short_ttft_no_worse", False),
         "chunked TTFT p50 <= padded TTFT p50 on the short-prompt trace")
    gate("engine/chunked_vs_padded_ttft_no_worse",
         eg.get("chunked_vs_padded_ttft_no_worse", False),
         "chunked TTFT p50 <= padded TTFT p50 on the main trace")

    # -- packed tick: structural ---------------------------------------
    gate("engine/packed_token_match",
         eg.get("packed_token_match", False),
         "packed serving token-identical to the chunked oracle on the "
         "main trace (kernel-match)")
    gate("engine/packed_concat_free",
         eg.get("packed_concat_free", False),
         f"cache-sized concats in the packed program="
         f"{eg.get('packed_cache_sized_concats')}")
    gate("engine/packed_vs_chunked_no_regression",
         eg.get("packed_vs_chunked_no_regression", False),
         "packed requests/kstep >= chunked on the main trace")
    gate("engine/packed_vs_gang_saturated",
         eg.get("packed_vs_gang_saturated", False),
         f"saturated-trace packed/gang throughput="
         f"x{eg.get('packed_vs_gang_saturated_speedup', 0.0):.2f} "
         "(must be >= 1)")
    gate("engine/packed_ttft_no_worse_saturated",
         eg.get("packed_ttft_no_worse_saturated", False),
         "packed TTFT p50 <= chunked TTFT p50 on the saturated trace")

    # -- paged prefix reuse: structural --------------------------------
    gate("engine/prefix_token_match",
         eg.get("prefix_token_match", False),
         "prefix-cache ON token-identical to OFF on the shared-prefix "
         "trace (COW never corrupts)")
    gate("engine/prefix_reuse_savings",
         eg.get("prefix_reuse_savings", 0.0) > 0,
         f"prefix reuse saved "
         f"{100 * eg.get('prefix_reuse_savings', 0.0):.1f}% of prefill "
         f"tokens ({eg.get('prefix_hits', 0)} hits; must be > 0)")
    gate("engine/prefix_ttft_no_worse",
         eg.get("prefix_ttft_no_worse", False),
         "prefix-ON TTFT p50 <= OFF on the shared-prefix trace")

    # -- host KV offload / preemption: structural ----------------------
    gate("engine/preempt_token_match",
         eg.get("preempt_token_match", False),
         "offload ON token-identical to OFF on the page-starved "
         "overload trace (spill/restore never corrupts)")
    gate("engine/preempt_fired",
         eg.get("preempt_fired", False),
         "the overload trace actually preempted and restored through "
         "the host store")
    gate("engine/preempt_ttft_no_worse",
         eg.get("preempt_ttft_no_worse", False),
         f"interactive-class TTFT p50 with preemption <= without "
         f"(speedup x"
         f"{eg.get('preempt_interactive_ttft_speedup', 0.0):.2f})")

    # -- chaos soak (fault injection): structural ----------------------
    chaos = engine_cur.get("traces", {}).get("chaos", {})
    fired = {name: c.get("faults_injected", 0)
             for name, c in chaos.items()}
    gate("engine/chaos_token_match",
         eg.get("chaos_token_match", False),
         "every request completed under seeded all-kinds fault "
         "injection is token-identical to the clean run (3 seeds; "
         f"faults fired per seed: {fired})")
    gate("engine/chaos_zero_leak",
         eg.get("chaos_zero_leak", False),
         "pages/state rows/store bytes/slots all reclaimed after the "
         "chaos drain on every seed")
    gate("engine/chaos_faults_fired",
         eg.get("chaos_faults_fired", False),
         "each chaos seed injected > 0 faults and completed > 0 "
         "requests")

    # -- degraded-mesh serving (shard loss): structural ----------------
    deg = engine_cur.get("traces", {}).get("degraded", {})
    gate("engine/degraded_streams_finite",
         eg.get("degraded_streams_finite", False),
         "every stream crossing the shard-loss window closed with "
         "exactly its requested finite token count (Segment-Means "
         "replicas carried the degraded ticks)")
    gate("engine/degraded_zero_leak",
         eg.get("degraded_zero_leak", False),
         "pages/state rows/slots all reclaimed after the shard-loss "
         "recovery drain")
    gate("engine/degraded_recovery_token_match",
         eg.get("degraded_recovery_token_match", False),
         f"post-recovery results token-identical to the clean run "
         f"(shard_lost={deg.get('shard_lost', 0)}, "
         f"degraded_ticks={deg.get('degraded_ticks', 0)}, "
         f"restarts={deg.get('restarts', 0)})")

    # -- async streaming loop: structural ------------------------------
    gate("engine/stream_token_match",
         eg.get("stream_token_match", False),
         "double-buffered streaming delivers exactly the synchronous "
         "engine's tokens on the identical main trace, every stream "
         "closed with a finish reason")
    gate("engine/stream_overlap_ran",
         eg.get("stream_overlap_ran", False),
         "the overlapped loop actually dispatched packed ticks")
    gate("engine/host_overhead_fraction",
         0.0 <= eg.get("host_overhead_fraction", 1.0) < 0.9,
         f"worst overlap-on host-overhead fraction="
         f"{eg.get('host_overhead_fraction', 1.0):.3f} (wall clock; "
         "coarse ceiling 0.9 — the loop must stay device-bound)")

    # -- engine bench: logical-clock throughput vs baseline ------------
    for mode in ("packed", "chunked"):
        cur = engine_cur["traces"]["main"][mode]["requests_per_ksteps"]
        base_row = engine_base["traces"]["main"].get(mode, {})
        base = base_row.get("requests_per_ksteps")
        if base is None:        # baseline predates this mode: skip
            continue
        floor = (1.0 - tolerance) * base
        gate(f"engine/{mode}_throughput_vs_baseline", cur >= floor,
             f"current={cur:.2f} baseline={base:.2f} floor={floor:.2f} "
             f"req/kstep (logical clock, deterministic)")

    # -- reported, never gated -----------------------------------------
    wall = {}
    for mode, row in engine_cur["traces"]["main"].items():
        b = engine_base["traces"]["main"].get(mode, {})
        wall[mode] = {
            "decode_ms": {"current": row.get("wall_decode_ms"),
                          "baseline": b.get("wall_decode_ms")},
            "prefill_ms": {"current": row.get("wall_prefill_ms"),
                           "baseline": b.get("wall_prefill_ms")},
        }
    speed = {
        "prism_concat_free_speedup": {
            "current": decode_cur.get("prism_concat_free_speedup"),
            "baseline": decode_base.get("prism_concat_free_speedup")},
    }
    # streaming wall-clock sweep: reported per offered load, never
    # gated beyond the coarse host-overhead ceiling above (TTFT/ITL in
    # wall seconds are CI-hardware-dependent)
    stream_wall = {}
    for rate_name, w in (engine_cur.get("traces", {})
                         .get("stream", {}).get("wall", {})).items():
        b = (engine_base.get("traces", {}).get("stream", {})
             .get("wall", {})).get(rate_name, {})
        stream_wall[rate_name] = {
            key: {"current": w.get(key), "baseline": b.get(key)}
            for key in ("overlap_on", "overlap_off")}
    return {"ok": all(g["ok"] for g in gates), "tolerance": tolerance,
            "gates": gates, "wall_ungated": wall,
            "stream_wall_ungated": stream_wall,
            "microbench_ungated": speed}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode-baseline", default="BENCH_decode.json")
    ap.add_argument("--decode-current", required=True)
    ap.add_argument("--engine-baseline", default="BENCH_engine.json")
    ap.add_argument("--engine-current", required=True)
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional throughput regression")
    ap.add_argument("--out", default=None,
                    help="write the comparison artifact here")
    args = ap.parse_args(argv)

    result = compare(_load(args.decode_baseline),
                     _load(args.decode_current),
                     _load(args.engine_baseline),
                     _load(args.engine_current),
                     args.tolerance)
    for g in result["gates"]:
        print(f"[{'PASS' if g['ok'] else 'FAIL'}] {g['gate']}: "
              f"{g['detail']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {args.out}")
    print("# bench-regression gate:", "OK" if result["ok"] else "FAILED")
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())

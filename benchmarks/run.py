"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table4,fig5]

Prints ``name,us_per_call,derived`` CSV lines (us_per_call is 0 for
analytic/accuracy rows).
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ("table4_vit", "table5_bert", "table6_gpt2", "fig5_latency",
          "microbench", "accuracy_vs_cr", "roofline_table",
          "engine_throughput")


def report(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    failures = []
    for suite in SUITES:
        if only and suite not in only and suite.split("_")[0] not in only:
            continue
        t0 = time.time()
        print(f"# ==== {suite} ====")
        try:
            mod = importlib.import_module(f"benchmarks.{suite}")
            mod.main(report)
            print(f"# {suite} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((suite, repr(e)))
            print(f"# {suite} FAILED: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Docs link check: every relative markdown link must resolve to a file
in the repo.  External (http/https/mailto) links and pure anchors are
skipped — no network in CI.

    python .github/check_doc_links.py
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
ROOT = pathlib.Path(__file__).resolve().parent.parent
# exemplar/abstract dumps quote external repos verbatim — their relative
# links point into repos we don't vendor
SKIP = {"SNIPPETS.md", "PAPERS.md", "PAPER.md"}


def main() -> int:
    bad = []
    for md in sorted(ROOT.rglob("*.md")):
        if ".git" in md.parts or md.name in SKIP:
            continue
        for target in LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                bad.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    for line in bad:
        print(line)
    print(f"checked markdown links under {ROOT.name}: "
          f"{'FAIL' if bad else 'OK'}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())

"""Batched serving demo: prefill a batch of prompts through the
sequence-sharded runtime, then decode tokens with the exact
(flash-decoding) and prism (Segment-Means cache) modes and compare.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.protocol import PrismConfig
    from repro.models import transformer as T
    from repro.runtime.serve import (ServeHParams, make_prefill_step,
                                     make_serve_step)

    if len(jax.devices()) < 8:
        print("set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        sys.exit(1)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("gemma3-1b").reduced()
    params = T.init(cfg, jax.random.PRNGKey(0))
    B, n, gen = 8, 64, 12
    cap = n + gen + (-(n + gen)) % 4
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, n), 1,
                                 cfg.vocab_size)

    outs = {}
    for mode in ("exact", "prism"):
        hp = ServeHParams(decode_mode=mode, means_cr=4.0, ssm_chunk=8)
        prism = PrismConfig(
            P=4, cr=4.0, mode="prism" if mode == "prism" else "voltage")
        prefill, lay_p, _, _ = make_prefill_step(
            cfg, mesh, params, prism, batch=B, n=n, hp=hp, cap=cap)
        logits, cache = prefill(params, {"tokens": prompts})
        step, lay_d, _, _ = make_serve_step(
            cfg, mesh, params, batch=B, cap=cap, prefill_len=n, hp=hp)
        assert lay_p == lay_d
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [np.asarray(tok)]
        for g in range(gen - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((B,), n + g, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(np.asarray(tok))
        outs[mode] = np.stack(toks, 1)
        print(f"[{mode:5s}] generated:\n{outs[mode][:3]}")

    agree = (outs["exact"] == outs["prism"]).mean()
    print(f"\nexact-vs-prism greedy token agreement: {agree:.1%} "
          "(prism approximates remote context by Segment Means; "
          "agreement rises with lower CR)")


if __name__ == "__main__":
    main()

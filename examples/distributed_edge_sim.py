"""Paper-faithful edge simulation: the master/worker protocol of Fig. 1
executed literally — a master partitions the input (Alg. 1), P 'device'
objects exchange Segment Means between blocks (no shard_map; explicit
per-device state), and the outputs are compared against single-device
inference, with per-block communication metered in bytes.

    PYTHONPATH=src python examples/distributed_edge_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.attention import prism_attention
from repro.core.protocol import PrismConfig, partition_bounds
from repro.core.segment_means import (segment_means, segment_bounds,
                                      segment_sizes)
from repro.core.masks import visibility, exact_cols
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import (attn_project_q, attn_project_kv,
                                 attn_output, mlp, norm)

cfg = ModelConfig(
    name="edge-sim", arch_type="dense", n_layers=3, d_model=96,
    n_heads=3, n_kv_heads=3, head_dim=32, d_ff=192, vocab_size=128,
    mlp_kind="gelu", norm_kind="rmsnorm", pos="rope")
P, CR, N = 3, 4.0, 48
params = T.init(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (1, N), 0, 128)


class EdgeDevice:
    """One worker: owns a partition, computes a block, publishes means."""

    def __init__(self, pid, x_p, start):
        self.pid, self.x, self.start = pid, x_p, start
        self.bytes_tx = 0

    def publish(self, L):
        z = segment_means(self.x, L)
        self.bytes_tx += (P - 1) * z.size * 4        # unicast, like paper
        lo, hi = segment_bounds(self.x.shape[1], L, offset=self.start)
        return z, lo, hi, segment_sizes(self.x.shape[1], L)

    def block(self, layer_params, remote, L):
        n_p = self.x.shape[1]
        others = [r for r in remote if r[0] != self.pid]
        z_all = jnp.concatenate([z for _, z, *_ in others], axis=1)
        x_hat = jnp.concatenate([self.x, z_all], axis=1)
        row = np.arange(n_p) + self.start
        lo = np.concatenate([row] + [o[2] for o in others])
        hi = np.concatenate([row] + [o[3] for o in others])
        g = np.concatenate([np.ones(n_p)]
                           + [o[4].astype(np.float64) for o in others])
        mask = visibility(jnp.asarray(row), jnp.asarray(lo),
                          jnp.asarray(hi), causal=True)
        spec = T.attn_spec(cfg, "attn")
        p = layer_params
        xq_n = norm(p["ln1"], self.x, cfg.norm_kind)
        xh_n = norm(p["ln1"], x_hat, cfg.norm_kind)
        mid = (lo + hi) / 2.0
        q = attn_project_q(p["attn"], spec, xq_n, jnp.asarray(row, jnp.float32))
        k, v = attn_project_kv(p["attn"], spec, xh_n,
                               jnp.asarray(mid, jnp.float32))
        o = prism_attention(q, k, v, g=jnp.asarray(g, jnp.float32),
                            mask=mask)
        x = self.x + attn_output(p["attn"], o)
        x = x + mlp(p["mlp"], norm(p["ln2"], x, cfg.norm_kind),
                    cfg.mlp_kind)
        self.x = x


def main():
    # master: embed + partition (Alg. 1)
    x = T.embed_inputs(cfg, params, tokens)
    L = max(1, int(N // (CR * P)))
    devices = [EdgeDevice(p, x[:, s:s + sz], s)
               for p, (s, sz) in enumerate(partition_bounds(N, P))]

    for kind, layer in T.iter_layers(cfg, params):
        remote = []
        for d in devices:
            z, lo, hi, sizes = d.publish(L)
            remote.append((d.pid, z, lo, hi, sizes))
        for d in devices:
            d.block(layer, remote, L)

    # master: gather partitions, final norm + head
    x_out = jnp.concatenate([d.x for d in devices], axis=1)
    x_out = norm(params["final_norm"], x_out, cfg.norm_kind)
    logits = x_out @ params["embed"]["table"].T

    ref, _ = T.forward(cfg, params, tokens)
    err = float(jnp.abs(logits - ref).max() / jnp.abs(ref).max())
    tx = sum(d.bytes_tx for d in devices)
    volt_tx = cfg.n_layers * P * (P - 1) * (N // P) * cfg.d_model * 4
    print(f"P={P} CR={CR} L={L}: rel-err vs single-device = {err:.3f}")
    print(f"bytes exchanged: PRISM {tx:,} vs Voltage {volt_tx:,} "
          f"({100 * (1 - tx / volt_tx):.1f}% saved)")
    assert tx < volt_tx / 2
    print("edge simulation OK")


if __name__ == "__main__":
    main()

"""Continuous-batching engine demo: six requests with staggered
arrivals share four decode slots over a (2 data x 4 model) host mesh —
late arrivals are prefilled and spliced into slots freed by earlier
evictions, while the surviving streams keep decoding.  With the
default token-packed mode, each engine tick with any prefill work runs
ONE compiled program over a flat mixed batch of decode + prompt tokens
(watch the 'packed' step kinds below).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/serve_engine.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.runtime.serve import ServeHParams
    from repro.serving import EngineConfig, SamplingParams, ServingEngine

    if len(jax.devices()) < 8:
        print("set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        sys.exit(1)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("gpt2-small").reduced()
    params = T.init(cfg, jax.random.PRNGKey(0))

    # EngineConfig is the one construction path: paged page-table cache
    # and (in exact mode) shared-prefix reuse are on by default
    eng = ServingEngine(cfg, mesh, params, EngineConfig(
        n_slots=4, prefill_len=32, max_cache=48,
        hp=ServeHParams(decode_mode="exact", ssm_chunk=8)))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(8, 33))).tolist()
               for _ in range(6)]
    # first four arrive immediately and fill the pool ...
    for p in prompts[:4]:
        eng.submit(p, max_new_tokens=10,
                   sampling=SamplingParams())        # greedy
    for _ in range(5):
        print(f"[demo] step -> {eng.step()}")
    # ... two more arrive mid-flight; they must wait for evictions
    for p in prompts[4:]:
        eng.submit(p, max_new_tokens=10)
    out = eng.run()

    for rid, toks in out.items():
        print(f"[demo] request {rid} ({len(prompts[rid])} prompt tokens) "
              f"-> {toks}")
    for k, v in eng.stats.summary().items():
        print(f"[demo] {k:22s} {v:.4f}" if isinstance(v, float)
              else f"[demo] {k:22s} {v}")
    for k, v in eng.kv_cache.stats().items():
        print(f"[demo] kv/{k:19s} {v}")


if __name__ == "__main__":
    main()

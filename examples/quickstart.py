"""Quickstart: the PRISM protocol in 60 lines.

Builds a tiny decoder, runs the same input three ways —
single-device, Voltage (full exchange), PRISM (Segment-Means exchange) —
and prints output agreement + the per-layer communication each mode costs.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.protocol import (PrismConfig,
                                 comm_elements_per_device_per_layer)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.context import SimulatedContext

cfg = ModelConfig(
    name="quickstart", arch_type="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=256,
    mlp_kind="swiglu", norm_kind="rmsnorm", pos="rope")

params = T.init(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 256)

single, _ = T.forward(cfg, params, tokens)

P = 4
results = {}
for mode, cr in (("voltage", 1.0), ("prism", 2.0), ("prism", 8.0)):
    pc = PrismConfig(P=P, cr=cr, mode=mode)
    logits, _ = T.forward(cfg, params, tokens,
                          ctx=SimulatedContext(pc))
    err = float(jnp.abs(logits - single).max() / jnp.abs(single).max())
    comm = comm_elements_per_device_per_layer(64, cfg.d_model, pc)
    name = f"{mode}(CR={cr})"
    results[name] = (err, comm)
    print(f"{name:16s} rel-err vs single = {err:.2e}   "
          f"comm/device/layer = {comm:8.0f} elements")

assert results["voltage(CR=1.0)"][0] < 1e-5, "Voltage must be exact"
assert results["prism(CR=8.0)"][1] < results["voltage(CR=1.0)"][1] / 5, \
    "PRISM must slash communication"
print("\nPRISM trades a small approximation error for a large "
      "communication saving — exactly the paper's pitch.")

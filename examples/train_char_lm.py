"""End-to-end driver: train a ~25M-param char-LM for a few hundred steps
with the PRISM-sharded training step (sequence parallelism over 'model',
FSDP over 'data', Segment-Means exchange per block), then evaluate bpc.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/train_char_lm.py --steps 300

Scale up with --d-model/--layers (the default is sized for this CPU
container; the same script drives the production mesh on real TPUs).
"""
import argparse
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--mode", default="prism",
                    choices=("prism", "voltage"))
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.core.protocol import PrismConfig
    from repro.data.pipeline import CharTokenizer, lm_batches, synthetic_text
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.optim import adamw_init
    from repro.runtime.train import make_train_step, TrainHParams

    data, model = (int(x) for x in args.mesh.split("x"))
    if len(jax.devices()) < data * model:
        print(f"note: {len(jax.devices())} devices < mesh {args.mesh}; "
              "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
        sys.exit(1)
    mesh = jax.make_mesh((data, model), ("data", "model"))

    tok = CharTokenizer()
    corpus = tok.encode(synthetic_text(1_000_000, seed=1))
    held = tok.encode(synthetic_text(50_000, seed=2))
    cfg = ModelConfig(
        name="char-lm-25m", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=4 * args.d_model,
        vocab_size=tok.vocab, mlp_kind="swiglu", norm_kind="rmsnorm",
        pos="rope", tie_embeddings=True)

    params = T.init(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params, mesh {args.mesh}, "
          f"mode={args.mode} CR={args.cr}")

    prism = PrismConfig(P=model, cr=args.cr, mode=args.mode)
    hp = TrainHParams(lr=1e-3, warmup=20, total_steps=args.steps,
                      loss_chunks=8)
    step, rules, psh, osh, bsh = make_train_step(cfg, mesh, params,
                                                 prism, hp)
    params = jax.device_put(params, psh)
    opt = jax.device_put(adamw_init(params), osh)

    it = lm_batches(corpus, batch=args.batch, seq=args.seq, seed=0)
    import time
    t0 = time.time()
    for i in range(args.steps):
        x, y = next(it)
        params, opt, m = step(params, opt,
                              jax.device_put({"tokens": x, "labels": y},
                                             bsh))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"bpc {float(m['loss']) / math.log(2):.3f}  "
                  f"gnorm {float(m['gnorm']):.2f}  "
                  f"{time.time() - t0:.0f}s")

    # held-out bpc, evaluated THROUGH the sharded PRISM step's loss
    ev = lm_batches(held, batch=args.batch, seq=args.seq, seed=9)
    tot = 0.0
    for _ in range(5):
        x, y = next(ev)
        # the step donates its inputs, so rethread params/opt; the loss
        # metric is computed BEFORE the update, so this is a clean eval
        params, opt, m = step(params, opt,
                              jax.device_put({"tokens": x, "labels": y},
                                             bsh))
        tot += float(m["loss"])
    print(f"held-out bpc ≈ {tot / 5 / math.log(2):.3f} "
          f"({args.mode}, CR={args.cr})")

    if args.ckpt_dir:
        from repro.checkpoint.io import save_checkpoint
        print("saved:", save_checkpoint(args.ckpt_dir, args.steps,
                                        jax.device_get(params)))


if __name__ == "__main__":
    main()
